"""Discrete-event simulator of the edge-cloud continuum testbed (§4 of the paper).

Reproduces the paper's experimental apparatus — 4 Raspberry-Pi-class edge
instances, an elastic cloud tier, a shared 100 MB/s edge->cloud link, a
ramped open-loop request generator — so that Table 2 (successful responses
per traffic policy) and Figure 2 (latency / CPU / memory / network time
series) can be regenerated deterministically on this machine.

Crucially the ``auto`` policy exercises the *real* controller from
``repro.core.offload`` (the same jitted code the live serving tier runs),
not a reimplementation: the simulator is the calibration harness for the
paper's Eqs (1)-(4).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import offload
from repro.core.metrics import MetricsRegistry
from repro.core.policy import AutoOffload, ControlLoop, Policy, PolicySpec
from repro.core.workloads import PROFILES, WorkloadProfile


@dataclasses.dataclass(frozen=True)
class SimConfig:
    duration_s: float = 600.0
    low_rps: float = 2.0
    high_rps: float = 16.0
    ramp_start_s: float = 60.0
    ramp_end_s: float = 240.0
    edge_instances: int = 4            # the paper's 4x Raspberry Pi 3B+
    edge_slots_per_instance: int = 1
    cloud_slots: int = 64
    link_bandwidth_Bps: float = 100e6  # paper: "maximum of 100MB/s"
    link_rtt_s: float = 0.04
    timeout_s: float = 10.0
    control_interval_s: float = 1.0    # Prometheus scrape cadence
    metric_interval_s: float = 5.0
    window: int = 64                   # latency window fed to Eq (1)
    mem_baseline_mb: float = 180.0
    # Knative queue-proxy semantics: per-instance request queue is bounded;
    # overflow is rejected immediately (503). Fast rejections are *part of*
    # the latency distribution Prometheus scrapes — they are what keeps
    # Eq (1) bimodal (and hence alive) under deep overload.
    queue_depth_per_slot: int = 8
    reject_latency_s: float = 0.005
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    policy: str
    workload: str
    successes: int
    failures: int
    times: np.ndarray              # (T,) metric timestamps
    latency_avg: np.ndarray        # (T,) mean completed latency per interval
    cpu_util: np.ndarray           # (T,) edge busy fraction
    mem_mb: np.ndarray             # (T,) edge resident memory
    net_MBps: np.ndarray           # (T,) edge->cloud egress
    offload_pct: np.ndarray        # (T,) controller output

    def summary(self) -> Dict[str, float]:
        return {
            "successes": self.successes,
            "failures": self.failures,
            "latency_avg": float(np.nanmean(self.latency_avg)),
            "cpu_peak": float(self.cpu_util.max(initial=0.0)),
            "net_peak_MBps": float(self.net_MBps.max(initial=0.0)),
        }


# Event kinds, ordered for deterministic tie-breaking.
_ARRIVAL, _EDGE_DONE, _CLOUD_DONE, _CONTROL, _METRIC = range(5)


def _service_sample(rng: np.random.Generator, mean: float, cv: float) -> float:
    """Lognormal service time with given mean and coefficient of variation."""
    sigma2 = np.log(1.0 + cv * cv)
    mu = np.log(mean) - 0.5 * sigma2
    return float(rng.lognormal(mu, np.sqrt(sigma2)))


class ContinuumSimulator:
    """One workload, one policy, one run."""

    def __init__(self, workload: str, policy: PolicySpec,
                 cfg: SimConfig = SimConfig(),
                 offload_cfg: Optional[offload.OffloadConfig] = None):
        if workload not in PROFILES:
            raise ValueError(f"unknown workload {workload!r}")
        self.profile: WorkloadProfile = PROFILES[workload]
        self.cfg = cfg
        self.policy = policy
        self.rng = np.random.default_rng(cfg.seed)
        self.metrics = MetricsRegistry([workload], capacity=max(cfg.window * 4, 256))
        # The same Policy/ControlLoop objects the live runtime drives —
        # the simulator is the calibration harness, not a reimplementation.
        self.policy_obj = Policy.parse(
            policy, offload_cfg=offload_cfg or offload.OffloadConfig(),
            link_bytes_per_s=cfg.link_bandwidth_Bps,
            req_bytes=self.profile.payload_bytes)
        self.offload_cfg = (self.policy_obj.cfg
                            if isinstance(self.policy_obj, AutoOffload)
                            else offload_cfg or offload.OffloadConfig())
        self.control = ControlLoop(self.policy_obj, 1, window=cfg.window,
                                   control_interval_s=cfg.control_interval_s)

    # ------------------------------------------------------------------
    def _rate(self, t: float) -> float:
        c = self.cfg
        if t < c.ramp_start_s:
            return c.low_rps
        if t >= c.ramp_end_s:
            return c.high_rps
        frac = (t - c.ramp_start_s) / (c.ramp_end_s - c.ramp_start_s)
        return c.low_rps + frac * (c.high_rps - c.low_rps)

    def run(self) -> SimResult:
        cfg, prof = self.cfg, self.profile
        events: List[Tuple[float, int, int, tuple]] = []
        seq = itertools.count()

        def push(t: float, kind: int, payload: tuple = ()):
            heapq.heappush(events, (t, next(seq), kind, payload))

        # --- state ----------------------------------------------------
        edge_slots = cfg.edge_instances * cfg.edge_slots_per_instance
        edge_busy = 0
        edge_queue: Deque[Tuple[float]] = deque()     # (arrival_time,)
        cloud_busy = 0
        cloud_queue: Deque[Tuple[float]] = deque()
        link_free_at = 0.0
        pct = float(self.control.R[0])
        successes = failures = 0
        arrivals_in_interval = 0
        bytes_in_interval = 0.0
        completed_lat: List[float] = []
        busy_integral = 0.0
        last_busy_t = 0.0

        ts, lat_s, cpu_s, mem_s, net_s, off_s = ([] for _ in range(6))

        def note_busy(t: float):
            nonlocal busy_integral, last_busy_t
            busy_integral += edge_busy / max(edge_slots, 1) * (t - last_busy_t)
            last_busy_t = t

        # --- seed events ------------------------------------------------
        push(self.rng.exponential(1.0 / self._rate(0.0)), _ARRIVAL)
        push(cfg.control_interval_s, _CONTROL)
        push(cfg.metric_interval_s, _METRIC)

        def start_edge(t: float, arr: float):
            nonlocal edge_busy, successes, failures
            note_busy(t)
            edge_busy += 1
            svc = _service_sample(self.rng, prof.edge_service_s, prof.cv)
            push(t + svc, _EDGE_DONE, (arr,))

        def start_cloud(t: float, arr: float):
            nonlocal cloud_busy
            cloud_busy += 1
            svc = _service_sample(self.rng, prof.cloud_service_s, prof.cv)
            push(t + svc, _CLOUD_DONE, (arr,))

        while events:
            t, _, kind, payload = heapq.heappop(events)
            if t > cfg.duration_s:
                break

            if kind == _ARRIVAL:
                arrivals_in_interval += 1
                to_cloud = self.rng.uniform() * 100.0 < pct
                if to_cloud:
                    # Serialize over the shared link (FIFO pipe model):
                    # saturation shows up as link_free_at running ahead of t.
                    xfer = prof.payload_bytes / cfg.link_bandwidth_Bps
                    start = max(t, link_free_at)
                    link_free_at = start + xfer
                    bytes_in_interval += prof.payload_bytes
                    ready = link_free_at + cfg.link_rtt_s
                    if cloud_busy < cfg.cloud_slots:
                        start_cloud(ready, t)
                    else:
                        cloud_queue.append((t,))
                else:
                    if edge_busy < edge_slots:
                        start_edge(t, t)
                    elif len(edge_queue) < edge_slots * cfg.queue_depth_per_slot:
                        edge_queue.append((t,))
                    else:
                        # queue-proxy overflow: immediate 503
                        failures += 1
                        self.metrics.record_latency(prof.name, cfg.reject_latency_s)
                push(t + self.rng.exponential(1.0 / self._rate(t)), _ARRIVAL)

            elif kind == _EDGE_DONE:
                (arr,) = payload
                note_busy(t)
                edge_busy -= 1
                lat = t - arr
                # Prometheus sees every completed request's latency,
                # successful or not; only the success *counter* is gated.
                self.metrics.record_latency(prof.name, lat)
                if lat <= cfg.timeout_s:
                    successes += 1
                    completed_lat.append(lat)
                else:
                    failures += 1
                # admit next from queue, dropping timed-out waiters
                while edge_queue:
                    (qarr,) = edge_queue.popleft()
                    if t - qarr > cfg.timeout_s:
                        failures += 1
                        self.metrics.record_latency(prof.name, t - qarr)
                        continue
                    start_edge(t, qarr)
                    break

            elif kind == _CLOUD_DONE:
                (arr,) = payload
                cloud_busy -= 1
                lat = t - arr
                if lat <= cfg.timeout_s:
                    successes += 1
                    completed_lat.append(lat)
                    # Cloud latencies are *not* fed to Eq (1): the paper's
                    # strategy "uses the request latency metrics of all the
                    # functions running at the Edge".
                else:
                    failures += 1
                while cloud_queue:
                    (qarr,) = cloud_queue.popleft()
                    if t - qarr > cfg.timeout_s:
                        failures += 1
                        continue
                    start_cloud(t, qarr)
                    break

            elif kind == _CONTROL:
                # One shared scrape-and-update cycle (ControlLoop): latency
                # windows + in-flight queue-age mixing + demand RPS — the
                # same code path the live EdgeCloudContinuum ticks.
                lat, valid = self.metrics.latency_windows(cfg.window)
                ages = [t - qarr for (qarr,) in edge_queue]
                R = self.control.step(lat, valid, queue_ages=[ages],
                                      arrivals=[arrivals_in_interval])
                pct = float(R[0])
                push(t + cfg.control_interval_s, _CONTROL)
                arrivals_in_interval = 0

            elif kind == _METRIC:
                note_busy(t)
                ts.append(t)
                lat_s.append(float(np.mean(completed_lat)) if completed_lat else np.nan)
                completed_lat.clear()
                cpu_s.append(busy_integral / cfg.metric_interval_s)
                busy_integral = 0.0
                active = edge_busy + len(edge_queue)
                mem_s.append(cfg.mem_baseline_mb + active * prof.mem_mb)
                net_s.append(bytes_in_interval / cfg.metric_interval_s / 1e6)
                bytes_in_interval = 0.0
                off_s.append(pct)
                push(t + cfg.metric_interval_s, _METRIC)

        # Drain: everything still queued at the end never completed.
        failures += len(edge_queue) + len(cloud_queue) + edge_busy + cloud_busy

        return SimResult(
            policy=str(self.policy), workload=prof.name,
            successes=successes, failures=failures,
            times=np.asarray(ts), latency_avg=np.asarray(lat_s),
            cpu_util=np.asarray(cpu_s), mem_mb=np.asarray(mem_s),
            net_MBps=np.asarray(net_s), offload_pct=np.asarray(off_s))


def run_policy_sweep(workload: str,
                     policies=(0.0, 25.0, 50.0, 75.0, 100.0, "auto"),
                     cfg: SimConfig = SimConfig()) -> Dict[str, SimResult]:
    """The paper's Table 2 row for one workload."""
    out: Dict[str, SimResult] = {}
    for p in policies:
        out[str(p)] = ContinuumSimulator(workload, p, cfg).run()
    return out
