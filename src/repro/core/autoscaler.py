"""Knative-KPA-style concurrency autoscaler (per function, per tier).

Knative's Pod Autoscaler drives replica count from observed concurrency
(requests in flight) over two windows: a long *stable* window and a short
*panic* window; scale-to-zero engages after an idle grace period. The same
state machine governs our serving instance pools — both in the discrete
event simulator and in the live two-tier runtime.

Kept in plain Python/numpy: this is control-plane logic that runs at
scrape cadence (1 Hz in the paper), not inside the jitted step.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Tuple

from repro.core.replication import AutoscalingPolicy


@dataclasses.dataclass
class AutoscalerState:
    replicas: int
    idle_since: float | None = None
    panic_until: float = -1.0


class Autoscaler:
    """One instance per (function, tier)."""

    def __init__(self, policy: AutoscalingPolicy,
                 stable_window_s: float = 60.0, panic_window_s: float = 6.0):
        self.policy = policy
        self.stable_window_s = stable_window_s
        self.panic_window_s = panic_window_s
        self._obs: Deque[Tuple[float, float]] = deque()   # (time, concurrency)
        self.state = AutoscalerState(replicas=max(policy.min_scale, 0))

    # ------------------------------------------------------------------
    def observe(self, t: float, concurrency: float) -> None:
        self._obs.append((t, concurrency))
        horizon = t - self.stable_window_s
        while self._obs and self._obs[0][0] < horizon:
            self._obs.popleft()

    def _avg(self, t: float, window: float) -> float:
        pts = [c for (ts, c) in self._obs if ts >= t - window]
        return sum(pts) / len(pts) if pts else 0.0

    # ------------------------------------------------------------------
    def desired(self, t: float) -> int:
        """Recompute desired replicas at time t (call at scrape cadence)."""
        pol = self.policy
        stable = self._avg(t, self.stable_window_s)
        panic = self._avg(t, self.panic_window_s)
        target = max(pol.target_concurrency, 1e-6)

        want_stable = math.ceil(stable / target)
        want_panic = math.ceil(panic / target)

        # Panic mode: short-window load exceeded threshold x what the current
        # replicas absorb -> scale up immediately and hold (no scale-down)
        # for a stable window.
        cur = self.state.replicas
        if cur > 0 and panic / max(cur * target, 1e-6) >= pol.panic_threshold:
            self.state.panic_until = t + self.stable_window_s
        in_panic = t < self.state.panic_until

        want = max(want_stable, want_panic) if in_panic else want_stable
        if in_panic:
            want = max(want, cur)          # never scale down in panic

        # Scale-to-zero grace.
        if want == 0:
            if self.state.idle_since is None:
                self.state.idle_since = t
            if (t - self.state.idle_since) < pol.scale_to_zero_grace_s or pol.min_scale > 0:
                want = max(1, pol.min_scale)
        else:
            self.state.idle_since = None

        want = min(max(want, pol.min_scale), pol.max_scale)
        self.state.replicas = want
        return want

    @property
    def replicas(self) -> int:
        return self.state.replicas
