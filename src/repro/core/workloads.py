"""The paper's four FaaS workloads as JAX function bodies.

§4.1: "matrix multiplication (MatMult), image processing (Image Proc.),
random I/O, and a combination of these three loads (Mixed)". These are the
request bodies the platform serves in examples/tests, and the source of the
simulator's service-time and payload constants.

Each body is a pure function of (key, size) so it jits once per size.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnums=(1,))
def matmult(key: jax.Array, n: int = 256) -> jnp.ndarray:
    """Dense matmul chain — CPU/MXU-bound."""
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float32)
    c = a @ b
    c = c @ b.T
    return jnp.tanh(c).mean()


@functools.partial(jax.jit, static_argnums=(1,))
def image_proc(key: jax.Array, hw: int = 128) -> jnp.ndarray:
    """Separable blur + sobel + normalize over an image — memory-bound."""
    img = jax.random.uniform(key, (1, hw, hw, 3), jnp.float32)
    k = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0], jnp.float32)
    k = (k / k.sum()).reshape(5, 1, 1, 1)
    blur_h = jax.lax.conv_general_dilated(
        img, jnp.broadcast_to(k, (5, 1, 3, 3)), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    sob = jnp.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], jnp.float32)
    sob = jnp.broadcast_to(sob.reshape(3, 3, 1, 1), (3, 3, 3, 3))
    edges = jax.lax.conv_general_dilated(
        blur_h, sob, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return (edges - edges.mean()).std()


@functools.partial(jax.jit, static_argnums=(1,))
def random_io(key: jax.Array, n: int = 1 << 16) -> jnp.ndarray:
    """Random gather/scatter over a buffer — latency/IO-bound stand-in."""
    buf = jnp.arange(n, dtype=jnp.float32)
    idx = jax.random.randint(key, (n // 4,), 0, n)
    vals = buf[idx]
    buf = buf.at[(idx * 7919) % n].add(vals * 0.5)
    return buf.sum()


@functools.partial(jax.jit, static_argnums=(1,))
def mixed(key: jax.Array, scale: int = 128) -> jnp.ndarray:
    """The paper's combined load: one of each, summed."""
    k1, k2, k3 = jax.random.split(key, 3)
    return (matmult(k1, scale) + image_proc(k2, scale) +
            random_io(k3, scale * scale)).sum()


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Simulator constants for one workload (per-tier service model).

    ``edge_service_s``/``cloud_service_s`` are mean service times on a
    single slot; ``payload_bytes`` is the request+response transfer that a
    cloud-routed request pushes over the edge->cloud link; ``mem_mb`` is the
    per-request resident footprint on the edge (Figure 2 "Memory").
    Values are calibrated to reproduce the paper's qualitative Table 2 /
    Figure 2 regimes (see benchmarks/table2_responses.py).
    """
    name: str
    fn: Callable
    edge_service_s: float
    cloud_service_s: float
    payload_bytes: float
    mem_mb: float
    cv: float = 0.10            # service-time CV (RPi service is near-deterministic)


PROFILES: Dict[str, WorkloadProfile] = {
    # MatMult: CPU-heavy on the edge, huge payloads (matrices) -> the
    # workload whose full offload saturates the 100 MB/s link in the paper.
    "matmult": WorkloadProfile("matmult", matmult,
                               edge_service_s=0.85, cloud_service_s=0.10,
                               payload_bytes=6.0e6, mem_mb=96.0),
    # Image processing: moderate CPU, moderate payloads.
    "image_proc": WorkloadProfile("image_proc", image_proc,
                                  edge_service_s=0.55, cloud_service_s=0.08,
                                  payload_bytes=2.5e6, mem_mb=48.0),
    # Random I/O: cheap compute, tiny payloads -> offloading helps most
    # (paper: 4852 -> 9408 successes from 0% to 100%).
    "io": WorkloadProfile("io", random_io,
                          edge_service_s=0.40, cloud_service_s=0.06,
                          payload_bytes=2.0e5, mem_mb=16.0),
    # Mixed: average of the three.
    "mixed": WorkloadProfile("mixed", mixed,
                             edge_service_s=0.60, cloud_service_s=0.08,
                             payload_bytes=2.9e6, mem_mb=56.0),
}
