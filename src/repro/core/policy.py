"""First-class traffic policies + the shared control loop.

The paper's offloading strategy (Eqs (1)-(4)) is one algorithm that must
govern *any* deployment of the platform.  Historically the repo had two
divergent, stringly-typed copies of the scrape-and-update cycle — one
inlined in :class:`repro.core.simulator.ContinuumSimulator`, one in the
live :class:`repro.serving.tiers.EdgeCloudContinuum`.  This module is the
single control plane both now consume:

  * :class:`Policy` — the protocol every traffic policy implements
    (``init_state / observe / update / route``), plus :meth:`Policy.parse`
    so the established shorthands (``0.0``..``100.0``, ``"auto"``,
    ``"auto+net"``, ``"auto+hedge"``, ``"auto+migrate"``) keep working
    everywhere.
  * Concrete policies wrapping the existing primitives:
      - :class:`StaticSplit`     — fixed percentage (paper Table 2 columns);
      - :class:`AutoOffload`     — the paper's Eqs (1)-(4) controller;
      - :class:`NetAwareOffload` — beyond-paper link-capacity cap (§4.2);
      - :class:`HedgedOffload`   — auto + p99 straggler hedging on top of
        :func:`repro.core.router.hedged_mask`;
      - :class:`MigratingOffload` — auto + live mid-stream migration of
        slot-resident requests once R_t crosses a threshold (the
        ``migrate`` modifier composes with ``net``/``hedge`` as well).
  * :class:`ControlLoop` — one scrape-and-update cycle: latency windows,
    in-flight queue-age mixing, demand RPS, policy update.  The simulator
    and the live continuum drive the *same* code, so their R_t
    trajectories on a shared trace are identical (pinned by tests).

Policies are control-plane objects (host-side numpy in/out); the heavy
math inside ``AutoOffload.update`` stays jitted.

Fleet scale: when every boundary runs an ``auto``-family policy the loop
*vectorizes* — all boundaries of all functions become rows of one stacked
(P, W) tensor and each control interval is a single jitted
:func:`repro.core.offload.offload_update_rows` call (P padded to a power
of two, so growth costs O(log F) compiles).  This is bit-identical to
stepping the boundaries one by one (pinned by the F in {1, 3, 257} golden
test).  For 10k-function fleets, ``eq1="sketch"`` additionally replaces
the exact sorted-window percentile with the decayed histogram sketch of
:mod:`repro.core.quantile`, fed by *fresh samples only*
(:meth:`ControlLoop.step_stream`) — sub-millisecond ticks at F=4096, at
the cost of the sketch's documented quantile error.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload, quantile, router

PolicySpec = Union[float, int, str, "Policy"]


class Policy:
    """Protocol + shared plumbing for traffic policies.

    A policy answers two questions each control interval:
      * ``update``: given the scraped latency windows, what percentage R_t
        of each function's traffic goes cloud-ward?
      * ``route``:  given R_t, which of the queued requests cross?

    ``init_state``/``observe`` let stateful policies carry their own state
    pytree through the loop without the harness knowing its shape.
    """

    #: canonical shorthand (used by ``parse`` round-trips and logs)
    spec: str = "policy"
    #: lazily-built jitted routers (shared by all route*() calls)
    _route_jit = None
    _route_tiers_jit = None
    #: Mid-stream migration knob: when set, the live continuous scheduler
    #: migrates slot-resident requests down-chain whenever this
    #: boundary's R_t reaches the threshold (percent) — in addition to
    #: routing new arrivals.  ``None`` disables migration (the default;
    #: routing-only is the paper's behaviour).
    migrate_threshold: Optional[float] = None
    #: A row is a migration victim only if it still has at least this
    #: many tokens to generate — nearly-done rows are cheaper to finish
    #: in place than to ship.
    migrate_min_remaining: int = 2

    # -- state ------------------------------------------------------------
    def init_state(self, num_functions: int) -> Any:
        """Build this policy's opaque state pytree for an F-function
        deployment.  The harness threads it through ``observe``/``update``
        without knowing its shape; stateless policies return None."""
        return None

    def initial_R(self, num_functions: int) -> np.ndarray:
        """R_t before the first update (Eq (4): R_t(0) = 0)."""
        return np.zeros(num_functions, np.float32)

    def observe(self, state: Any, latencies: np.ndarray,
                valid: np.ndarray) -> Any:
        """Optional scrape-time hook, called every control interval with
        the mixed (F, W) window *before* ``update`` — even on intervals
        where ``update`` is skipped because nothing was observed.  The
        default is a no-op; a policy that feeds its own sketch or log
        overrides it and returns the evolved state."""
        return state

    # -- control ----------------------------------------------------------
    def update(self, state: Any, latencies: np.ndarray, valid: np.ndarray,
               demand_rps: np.ndarray) -> Tuple[Any, np.ndarray]:
        """One controller step -> (new_state, (F,) percentages).

        Args:
          state: whatever ``init_state`` returned (threaded, opaque).
          latencies, valid: (F, W) scraped latency window and its
            observation mask, queue ages already mixed in.
          demand_rps: (F,) request rate seen this interval (net-aware
            policies cap R_t by what the link absorbs at this demand).

        Returns ``(new_state, R)`` with R in percent of traffic to send
        down-chain (0 = keep everything local, 100 = offload all).
        """
        raise NotImplementedError

    def route(self, key: jax.Array, R: np.ndarray, fn_ids: np.ndarray,
              num_functions: int) -> np.ndarray:
        """Split a batch by R_t -> (B,) bool mask, True = cloud.

        The batch is padded to a power-of-two bucket under one jitted
        ``route_batch`` (padding rows carry a void function id with pct 0),
        so live ticks with ever-changing queue depths reuse a handful of
        compiled shapes instead of recompiling the sort every tick.
        """
        B = len(fn_ids)
        if B == 0:
            return np.zeros(0, bool)
        if self._route_jit is None:
            self._route_jit = jax.jit(router.route_batch,
                                      static_argnums=(3,))
        Bp = max(1, 1 << (B - 1).bit_length())
        ids = np.full(Bp, num_functions, np.int32)
        ids[:B] = fn_ids
        pct = np.zeros(num_functions + 1, np.float32)
        pct[:num_functions] = R
        mask = self._route_jit(key, jnp.asarray(pct), jnp.asarray(ids),
                               num_functions + 1)
        return np.asarray(mask)[:B]

    def tier_distribution(self, R_all: np.ndarray,
                          num_tiers: int) -> np.ndarray:
        """Compose per-boundary percentages into a tier distribution.

        ``R_all`` is (num_tiers-1, F): boundary b's R_t is the percentage
        of the traffic *reaching* tier b that continues to tier b+1 (the
        waterfall reading of the paper's single edge->cloud R_t).  Returns
        (F, num_tiers) percentages summing to 100; for two tiers this is
        exactly ``[100 - R, R]``.
        """
        R_all = np.asarray(R_all, np.float32)
        F = R_all.shape[1]
        d = np.zeros((F, num_tiers), np.float32)
        remain = np.full(F, 100.0, np.float32)
        for b in range(num_tiers - 1):
            d[:, b] = remain * (100.0 - R_all[b]) / 100.0
            remain = remain * R_all[b] / 100.0
        d[:, num_tiers - 1] = remain
        return d

    def route_tiers(self, key: jax.Array, dist: np.ndarray,
                    fn_ids: np.ndarray, num_functions: int) -> np.ndarray:
        """Assign a batch over N tiers by the (F, N) distribution.

        Returns (B,) int tier indices.  Batches are padded to a
        power-of-two bucket (padding rows carry a void function id that
        routes 100% to tier 0) so live ticks reuse compiled shapes.
        """
        B = len(fn_ids)
        num_tiers = dist.shape[1]
        if B == 0:
            return np.zeros(0, np.int32)
        if num_tiers == 1:
            return np.zeros(B, np.int32)
        if self._route_tiers_jit is None:
            self._route_tiers_jit = jax.jit(router.route_tiers)
        Bp = max(1, 1 << (B - 1).bit_length())
        ids = np.full(Bp, num_functions, np.int32)
        ids[:B] = fn_ids
        distp = np.zeros((num_functions + 1, num_tiers), np.float32)
        distp[:num_functions] = dist
        distp[num_functions, 0] = 100.0
        tiers = self._route_tiers_jit(key, jnp.asarray(distp),
                                      jnp.asarray(ids))
        return np.asarray(tiers)[:B]

    def hedge(self, key: jax.Array, ages_s: np.ndarray, fn_ids: np.ndarray,
              latencies: np.ndarray, valid: np.ndarray) -> np.ndarray:
        """Which waiting requests deserve a backup on the other tier."""
        return np.zeros(len(fn_ids), bool)

    # -- parsing ----------------------------------------------------------
    @staticmethod
    def parse(spec: PolicySpec,
              offload_cfg: Optional[offload.OffloadConfig] = None,
              link_bytes_per_s: Optional[float] = None,
              req_bytes: Optional[float] = None) -> "Policy":
        """Turn the established shorthands into Policy objects.

        Grammar (see docs/policies.md for the full catalog):

        * ``0.0``..``100.0`` (number or numeric string) -> StaticSplit.
        * ``"auto"`` -> AutoOffload, optionally followed by any
          combination of the three modifiers, in any order:
          ``+net`` (link-capacity cap -> NetAwareOffload),
          ``+hedge`` (p99 straggler backups -> HedgedOffload),
          ``+migrate`` (mid-stream migration -> MigratingOffload).
          Modifiers compose — ``"auto+net+hedge+migrate"`` is one policy
          with all three behaviours; when several classes could host the
          combination the net/hedge class wins and ``migrate`` attaches
          as its threshold attribute.  The canonical ``spec`` string is
          re-normalized to net, hedge, migrate order.
        * Policy instances pass through untouched, so callers can accept
          "policy-or-shorthand" uniformly.

        Anything else raises ``ValueError``.
        """
        if isinstance(spec, Policy):
            return spec
        cfg = offload_cfg or offload.OffloadConfig()
        if isinstance(spec, (int, float)):
            return StaticSplit(float(spec))
        if isinstance(spec, str):
            s = spec.strip().lower()
            try:
                return StaticSplit(float(s))
            except ValueError:
                pass
            parts = s.split("+")
            mods = set(parts[1:])
            if parts[0] == "auto" and mods <= {"net", "hedge", "migrate"}:
                if "net" in mods:
                    net = NetAwareOffload(cfg,
                                          link_bytes_per_s=link_bytes_per_s,
                                          req_bytes=req_bytes)
                    pol = HedgedOffload(net.cfg) if "hedge" in mods else net
                elif "hedge" in mods:
                    pol = HedgedOffload(cfg)
                elif "migrate" in mods:
                    pol = MigratingOffload(cfg)
                else:
                    pol = AutoOffload(cfg)
                if "migrate" in mods and pol.migrate_threshold is None:
                    # the modifier composes with net/hedge variants too
                    pol.migrate_threshold = MigratingOffload.default_threshold
                pol.spec = "auto" + "".join(
                    "+" + m for m in ("net", "hedge", "migrate")
                    if m in mods)
                return pol
        raise ValueError(f"unknown policy spec {spec!r}")


class StaticSplit(Policy):
    """Fixed percentage of traffic to the cloud (the 0/25/50/75/100 columns
    of the paper's Table 2)."""

    def __init__(self, pct: float):
        if not 0.0 <= pct <= 100.0:
            raise ValueError(f"static split must be in [0, 100], got {pct}")
        self.pct = float(pct)
        self.spec = str(self.pct)

    def initial_R(self, num_functions: int) -> np.ndarray:
        return np.full(num_functions, self.pct, np.float32)

    def update(self, state, latencies, valid, demand_rps):
        return state, np.full(latencies.shape[0], self.pct, np.float32)


class AutoOffload(Policy):
    """The paper's adaptive controller: Eqs (1)-(4) on edge latency windows.

    The update runs through the module-level batched rows kernel
    (:func:`repro.core.offload.offload_update_rows`): rows are padded to
    :func:`repro.core.offload.padded_rows` and the per-link net-cap
    arrives as data, so every boundary of every deployment shares one
    compilation per (P, W) shape and a capacity change never recompiles.
    """

    spec = "auto"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None):
        self.cfg = cfg or offload.OffloadConfig()

    def _structural_cfg(self) -> offload.OffloadConfig:
        """The jit-static residue of ``cfg``: only the Eq-(2)/(3)/(4)
        constants.  Net-aware fields are data in the rows kernel, so
        policies differing only in link capacity share a compilation."""
        return offload.OffloadConfig(
            c_decay=self.cfg.c_decay, c_t=self.cfg.c_t,
            c_soft=self.cfg.c_soft, c_hard=self.cfg.c_hard,
            c_in=self.cfg.c_in)

    def net_rows(self, num_rows: int) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
        """(link_x100, req_bytes, net_mask) rows for the batched kernel.

        ``link_x100`` is ``100 * link_bytes_per_s`` pre-rounded to float32
        on the host — the same value the scalar path constant-folds — so
        the batched cap is bit-identical to the legacy one.
        """
        if self.cfg.net_aware:
            return (np.full(num_rows, np.float32(
                        100.0 * self.cfg.link_bytes_per_s), np.float32),
                    np.full(num_rows, np.float32(self.cfg.req_bytes),
                            np.float32),
                    np.ones(num_rows, bool))
        return (np.zeros(num_rows, np.float32),
                np.ones(num_rows, np.float32),
                np.zeros(num_rows, bool))

    def init_state(self, num_functions: int) -> offload.OffloadState:
        return offload.OffloadState.init_rows(
            offload.padded_rows(num_functions), self.cfg)

    def update(self, state, latencies, valid, demand_rps):
        lat = np.asarray(latencies, np.float32)
        F, W = lat.shape
        P = state.ratios.shape[0]
        lat_p = np.zeros((P, W), np.float32)
        val_p = np.zeros((P, W), bool)
        lat_p[:F] = lat
        val_p[:F] = valid
        active = np.zeros(P, bool)
        active[:F] = True
        rps = np.full(P, 1e-3, np.float32)
        rps[:F] = np.asarray(demand_rps, np.float32)
        link_x100, req_b, net_mask = self.net_rows(P)
        state, R = offload.offload_update_rows_jit(
            state, lat_p, val_p, active, link_x100, req_b, net_mask, rps,
            cfg=self._structural_cfg())
        return state, np.asarray(R, np.float32)[:F]

    def set_link_capacity(self, link_bytes_per_s: float) -> bool:
        """Re-cap a net-aware controller against a changed link (fault
        injection: brownout/partition shrinks the capacity, recovery
        restores it).

        The capacity is a *data* input of the batched rows kernel (read
        back from ``self.cfg`` on every update), so replacing the config
        is sufficient — no recompile.  Controller state (the boundary's
        OffloadState, held by the ControlLoop) is untouched: only the
        capacity the next Eq-(4) cap divides by changes.  No-op (False)
        for non-net-aware configs, whose updates never read the link.
        """
        if not self.cfg.net_aware:
            return False
        self.cfg = dataclasses.replace(
            self.cfg, link_bytes_per_s=float(link_bytes_per_s))
        return True


class NetAwareOffload(AutoOffload):
    """Beyond-paper §4.2 extension: cap the offloaded fraction by what the
    edge->cloud link can absorb at the current demand."""

    spec = "auto+net"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 link_bytes_per_s: Optional[float] = None,
                 req_bytes: Optional[float] = None):
        cfg = cfg or offload.OffloadConfig()
        repl: Dict[str, Any] = {"net_aware": True}
        if link_bytes_per_s is not None:
            repl["link_bytes_per_s"] = link_bytes_per_s
        if req_bytes is not None:
            repl["req_bytes"] = req_bytes
        super().__init__(dataclasses.replace(cfg, **repl))


class HedgedOffload(AutoOffload):
    """Auto controller + request-level straggler mitigation: a queued
    request whose age already exceeds its function's p99 gets a backup
    issued on the other tier (``router.hedged_mask``)."""

    spec = "auto+hedge"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 hedge_quantile: float = 0.99):
        super().__init__(cfg)
        self.hedge_quantile = float(hedge_quantile)

    def hedge(self, key, ages_s, fn_ids, latencies, valid):
        if len(fn_ids) == 0:
            return np.zeros(0, bool)
        p = self._tail_estimate(latencies, valid)
        return np.asarray(router.hedged_mask(
            key, jnp.asarray(ages_s, jnp.float32), jnp.asarray(p),
            jnp.asarray(fn_ids, jnp.int32)))

    def _tail_estimate(self, latencies, valid) -> np.ndarray:
        """(F,) per-function tail latency; +inf where nothing was observed
        yet (never hedge blind)."""
        lat = np.where(valid, np.asarray(latencies, np.float32), np.nan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)  # all-NaN rows
            p = np.nanpercentile(lat, self.hedge_quantile * 100.0, axis=-1)
        return np.where(np.isfinite(p), p, np.inf).astype(np.float32)


class MigratingOffload(AutoOffload):
    """Auto controller + live mid-stream migration (``"auto+migrate"``).

    Routing alone only redirects *new arrivals*: once a request is
    admitted into a tier's continuous-batching slots it is pinned there,
    so a burst of long decodes holds the slots hostage while R_t
    uselessly diverts fresh traffic.  With this variant, whenever a
    boundary's R_t reaches ``migrate_threshold`` the live scheduler also
    selects ``ceil(eligible * R_t / 100)`` slot-resident victims
    (longest-remaining first), ships their KV/state rows over the
    boundary's link (real cache bytes + token tail on the request's
    latency clock) and resumes them down-chain without re-prefill.  A
    landing that finds the destination full is *aborted*: the row
    resumes at its source, never lost.
    """

    spec = "auto+migrate"
    default_threshold = 50.0

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 migrate_threshold: float = default_threshold,
                 migrate_min_remaining: int = 2):
        super().__init__(cfg)
        self.migrate_threshold = float(migrate_threshold)
        self.migrate_min_remaining = int(migrate_min_remaining)


class ControlLoop:
    """The shared scrape-and-update cycle (one per deployment).

    Each :meth:`step` is exactly what the paper's controller does once per
    Prometheus scrape: read the per-function latency windows, mix in the
    ages of *in-flight* queued requests (Knative's queue-proxy exposes
    queue depth/age gauges — the ages are what let Eq (1) fire during
    onset, before slow completions drain out), derive demand RPS, and ask
    the policy for fresh R_t percentages.

    Over an N-tier :class:`~repro.core.topology.Topology`, the loop keeps
    one controller *boundary* between each pair of adjacent tiers
    (``num_tiers - 1`` of them).  Boundary b is driven by tier b's latency
    windows and yields R_t[b] — the percentage of tier b's load to push
    down the chain (waterfall offloading).  The classic two-tier continuum
    is the single-boundary special case; :meth:`step` remains its
    unchanged (bit-identical) code path.

    Both :class:`~repro.core.simulator.ContinuumSimulator` and the live
    :class:`~repro.serving.tiers.EdgeCloudContinuum` drive this object, so
    a shared latency trace yields bit-identical R_t trajectories.

    Vectorization: with ``vectorized="auto"`` (default) the loop detects
    fleets where every boundary runs an unmodified ``auto``-family policy
    with shared Eq-(2)/(3)/(4) constants and, instead of a per-boundary
    Python loop, advances ALL boundaries of ALL functions as rows of one
    stacked state in a single jitted call per tick — bit-identical to the
    per-boundary path (the parity oracle, still selectable with
    ``vectorized=False``).  ``eq1`` picks the Eq-(1) front end:
    ``"window"`` (exact sorted-window percentiles, the default and the
    golden-pinned path) or ``"sketch"`` (streaming histogram quantiles fed
    by :meth:`step_stream` — approximate, but O(F) sort-free ticks that
    stay sub-millisecond at F=4096).
    """

    def __init__(self, policy: PolicySpec, num_functions: int,
                 window: int = 64, control_interval_s: float = 1.0,
                 num_tiers: int = 2,
                 boundary_policies: Optional[Sequence[PolicySpec]] = None,
                 vectorized: Union[bool, str] = "auto",
                 eq1: str = "window",
                 sketch: Optional[quantile.SketchSpec] = None):
        if num_tiers < 1:
            raise ValueError(f"num_tiers must be >= 1, got {num_tiers}")
        if eq1 not in ("window", "sketch"):
            raise ValueError(f'eq1 must be "window" or "sketch", got {eq1!r}')
        self.num_functions = num_functions
        self.window = window
        self.control_interval_s = control_interval_s
        self.num_tiers = int(num_tiers)
        self.num_boundaries = max(self.num_tiers - 1, 1)
        if boundary_policies is None:
            self.policy = Policy.parse(policy)
            self.policies = [self.policy] * self.num_boundaries
        else:
            # Per-boundary policy objects (e.g. auto+net with each
            # boundary's own link capacity); boundary 0 is canonical for
            # routing/hedging.
            if len(boundary_policies) != self.num_boundaries:
                raise ValueError(
                    f"{self.num_boundaries} boundaries need "
                    f"{self.num_boundaries} policies, "
                    f"got {len(boundary_policies)}")
            self.policies = [Policy.parse(p) for p in boundary_policies]
            self.policy = self.policies[0]
        self.eq1 = eq1
        vec_ok = self._vectorizable()
        if vectorized == "auto":
            # On the exact path, F=1 multi-boundary stays on the
            # per-boundary loop: each boundary's seed-pinned trajectory
            # comes from a (1, W) compilation whose Eq-(4) FMA
            # contraction a (B, W) stack doesn't reproduce (see
            # offload.padded_rows), and there is nothing to vectorize
            # over at one function.  The sketch path has no bit contract,
            # so it always batches.
            self.vectorized = vec_ok and (
                eq1 == "sketch" or not (
                    num_functions == 1 and self.num_boundaries > 1))
        else:
            self.vectorized = bool(vectorized)
            if self.vectorized and not vec_ok:
                raise ValueError(
                    "vectorized=True needs every boundary on an unmodified "
                    "auto-family policy with shared controller constants")
        if eq1 == "sketch" and not self.vectorized:
            raise ValueError('eq1="sketch" requires the vectorized loop '
                             "(auto-family policies on every boundary)")
        if self.vectorized:
            # One stacked per-row-head state: row b*F+f is (boundary b,
            # function f); padded to a power of two so fleet growth costs
            # O(log F) compilations.
            self._rows = self.num_boundaries * num_functions
            self._P = offload.padded_rows(self._rows)
            self._structural = self.policies[0]._structural_cfg()
            self._vstate = offload.OffloadState.init_rows(
                self._P, self._structural)
            self._states = None
            self._net_cache = None
            if eq1 == "sketch":
                self.sketch_spec = sketch or quantile.SketchSpec()
                self._hist = quantile.Histogram.init(
                    self._P, self.sketch_spec.num_buckets,
                    self.sketch_spec.lo, self.sketch_spec.hi)
                self._decay_j = jnp.float32(self.sketch_spec.decay)
                # A boundary becomes (and stays) active once it has ever
                # produced a sample — the sketch analogue of "the window
                # retains observations", which is what gates updates on
                # the exact path.
                self._seen = np.zeros(self.num_boundaries, bool)
                self._active_j = None       # device mirror of _seen rows
                self._seen_snap = None
        else:
            self._states = [self.policies[b].init_state(num_functions)
                            for b in range(self.num_boundaries)]
        self.R_all = np.stack([self.policies[b].initial_R(num_functions)
                               for b in range(self.num_boundaries)])
        self.steps = 0

    def _vectorizable(self) -> bool:
        """True when every boundary can batch into one rows-kernel call:
        unmodified auto-family policies (no custom update/observe/state
        hooks) sharing the structural Eq-(2)/(3)/(4) constants.  Net-aware
        fields may differ per boundary — they are data, not structure."""
        pols = self.policies
        if not all(isinstance(p, AutoOffload) for p in pols):
            return False
        if not all(type(p).update is AutoOffload.update
                   and type(p).observe is Policy.observe
                   and type(p).init_state is AutoOffload.init_state
                   for p in pols):
            return False
        return len({(p.cfg.c_decay, p.cfg.c_t, p.cfg.c_soft,
                     p.cfg.c_hard, p.cfg.c_in) for p in pols}) == 1

    # Per-boundary state views.  In vectorized mode these are slices of
    # the stacked state (read-only snapshots); the legacy loop owns a real
    # per-boundary list.
    @property
    def states(self):
        if not self.vectorized:
            return self._states
        F = self.num_functions
        s = self._vstate
        return [offload.OffloadState(
                    s.ratios[b * F:(b + 1) * F], s.head[b * F:(b + 1) * F],
                    s.filled[b * F:(b + 1) * F], s.R[b * F:(b + 1) * F])
                for b in range(self.num_boundaries)]

    # 2-tier compatibility views: the ingress boundary's state and R_t.
    @property
    def state(self):
        return self.states[0]

    @state.setter
    def state(self, v):
        if self.vectorized:
            F = self.num_functions
            s = self._vstate
            self._vstate = offload.OffloadState(
                s.ratios.at[:F].set(v.ratios),
                s.head.at[:F].set(jnp.broadcast_to(v.head, (F,))),
                s.filled.at[:F].set(v.filled),
                s.R.at[:F].set(v.R))
        else:
            self._states[0] = v

    @property
    def R(self) -> np.ndarray:
        return self.R_all[0]

    @R.setter
    def R(self, v):
        self.R_all[0] = np.asarray(v, np.float32)

    @staticmethod
    def _sample_ages(ages: Sequence[float], window: int) -> List[float]:
        """Evenly subsample up to ``window // 2`` in-flight ages.

        The even spread across the queue (new arrivals vs head-of-line)
        is the bimodality Eq (1) keys on; both Eq-(1) front ends — the
        window mixing below and the streaming sketch ingest — must select
        the identical subset.
        """
        k = min(len(ages), window // 2)
        return [ages[int(i * len(ages) / k)] for i in range(k)] if k else []

    @staticmethod
    def mix_queue_ages(lat: np.ndarray, valid: np.ndarray, fn: int,
                       ages: Sequence[float], window: int) -> None:
        """Displace the oldest completions of function ``fn`` with a spread
        of in-flight queue ages (in place).

        Sampling is even across the queue (see :meth:`_sample_ages`); the
        ages overwrite the *oldest* window entries so fresh queue state
        dominates stale (often timeout-censored) history.
        """
        sel = ControlLoop._sample_ages(ages, window)
        if sel:
            lat[fn, :len(sel)] = sel
            valid[fn, :len(sel)] = True

    def _rps(self, arrivals: Optional[Sequence[float]]) -> np.ndarray:
        """Arrival counts -> (F,) demand RPS, floored at 1e-3.

        Vectorized but bit-identical to the historical per-element Python
        ``max(a / interval, 1e-3)``: the division happens in float64 and
        only the result is rounded to float32.
        """
        if arrivals is None:
            return np.full(self.num_functions, np.float32(1e-3), np.float32)
        a = np.asarray(arrivals, np.float64)
        return np.maximum(a / self.control_interval_s, 1e-3).astype(
            np.float32)

    def _step_boundary(self, b: int, latencies: np.ndarray,
                       valid: np.ndarray,
                       queue_ages: Optional[Sequence[Sequence[float]]],
                       rps: np.ndarray) -> np.ndarray:
        pol = self.policies[b]
        lat = np.array(latencies, np.float32, copy=True)
        val = np.array(valid, bool, copy=True)
        if queue_ages is not None:
            for fn, ages in enumerate(queue_ages):
                if ages:
                    self.mix_queue_ages(lat, val, fn, ages, self.window)
        self._states[b] = pol.observe(self._states[b], lat, val)
        if val.any():
            self._states[b], R = pol.update(self._states[b], lat, val, rps)
            self.R_all[b] = np.asarray(R, np.float32)
        return self.R_all[b]

    def _net_row_arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Stacked per-row net-cap inputs, re-read from each boundary's
        ``pol.cfg`` every tick so a mid-run ``set_link_capacity`` (fault
        injection) re-caps without recompiling anything.  Cached as
        device-resident arrays keyed on the cfg values — unchanged ticks
        skip both the rebuild and the host->device copies (which would
        otherwise eat a measurable slice of the sub-ms tick budget)."""
        key = tuple(pol.cfg for pol in self.policies)
        if self._net_cache is not None and key == self._net_cache[0]:
            return self._net_cache[1]
        F, P = self.num_functions, self._P
        link_x100 = np.zeros(P, np.float32)
        req_b = np.ones(P, np.float32)
        net_mask = np.zeros(P, bool)
        for b, pol in enumerate(self.policies):
            lo = b * F
            link_x100[lo:lo + F], req_b[lo:lo + F], net_mask[lo:lo + F] = \
                pol.net_rows(F)
        arrays = (jnp.asarray(link_x100), jnp.asarray(req_b),
                  jnp.asarray(net_mask))
        self._net_cache = (key, arrays)
        return arrays

    def _step_vectorized(self, latencies: Sequence[Optional[np.ndarray]],
                         valid: Sequence[Optional[np.ndarray]],
                         queue_ages: Optional[Sequence],
                         per_b_rps: Sequence[np.ndarray]) -> None:
        """Advance every boundary in ONE jitted rows-kernel call.

        ``latencies[b] is None`` marks a boundary that is not stepped this
        interval (``step`` only drives boundary 0); a stepped boundary
        with no valid observation after age mixing is frozen exactly like
        the legacy per-boundary ``val.any()`` skip.
        """
        F, B, P = self.num_functions, self.num_boundaries, self._P
        W = next(np.shape(l)[1] for l in latencies if l is not None)
        lat = np.zeros((P, W), np.float32)
        val = np.zeros((P, W), bool)
        active = np.zeros(P, bool)
        rps = np.full(P, 1e-3, np.float32)
        for b in range(B):
            if latencies[b] is None:
                continue
            lo = b * F
            lat[lo:lo + F] = latencies[b]
            val[lo:lo + F] = valid[b]
            qa = queue_ages[b] if queue_ages is not None else None
            if qa is not None:
                sub_lat, sub_val = lat[lo:lo + F], val[lo:lo + F]
                for fn, ages in enumerate(qa):
                    if ages:
                        self.mix_queue_ages(sub_lat, sub_val, fn, ages,
                                            self.window)
            active[lo:lo + F] = val[lo:lo + F].any()
            rps[lo:lo + F] = per_b_rps[b]
        link_x100, req_b, net_mask = self._net_row_arrays()
        self._vstate, R = offload.offload_update_rows_jit(
            self._vstate, lat, val, active, link_x100, req_b, net_mask,
            rps, cfg=self._structural)
        self.R_all = np.array(R, np.float32)[:B * F].reshape(B, F)

    def step(self, latencies: np.ndarray, valid: np.ndarray,
             queue_ages: Optional[Sequence[Sequence[float]]] = None,
             arrivals: Optional[Sequence[float]] = None) -> np.ndarray:
        """One control interval on the ingress boundary -> (F,) R_t.

        Deeper boundaries (if any) are left untouched; use
        :meth:`step_tiers` to advance the whole chain.

        Args:
          latencies, valid: (F, W) scraped windows (oldest entry first).
          queue_ages: per-function ages (seconds) of requests still
            waiting at the gateway, head-of-line first.
          arrivals: per-function request count seen this interval.

        Returns the ingress boundary's (F,) R_t percentages.
        """
        if self.eq1 == "sketch":
            raise ValueError('eq1="sketch" loops are driven by '
                             "step_stream(), not step()")
        rps = self._rps(arrivals)
        if self.vectorized:
            none = [None] * (self.num_boundaries - 1)
            self._step_vectorized([latencies] + none, [valid] + none,
                                  [queue_ages] + none if queue_ages
                                  is not None else None,
                                  [rps] * self.num_boundaries)
            out = self.R_all[0]
        else:
            out = self._step_boundary(0, latencies, valid, queue_ages, rps)
        self.steps += 1
        return out

    def _per_boundary_rps(self, arrivals: Optional[Sequence]
                          ) -> List[np.ndarray]:
        """Resolve the ``arrivals`` argument of :meth:`step_tiers` /
        :meth:`step_stream` into per-boundary (F,) RPS arrays."""
        if (arrivals is not None and len(arrivals)
                and isinstance(arrivals[0], (list, tuple, np.ndarray))):
            if len(arrivals) != self.num_boundaries:
                raise ValueError(
                    f"{self.num_boundaries} boundaries need "
                    f"{self.num_boundaries} arrival counts, "
                    f"got {len(arrivals)}")
            return [self._rps(a) for a in arrivals]
        return [self._rps(arrivals)] * self.num_boundaries

    def step_tiers(self, latencies: Sequence[np.ndarray],
                   valid: Sequence[np.ndarray],
                   queue_ages: Optional[Sequence] = None,
                   arrivals: Optional[Sequence[float]] = None) -> np.ndarray:
        """One control interval over every boundary of the chain.

        On a vectorized loop this is ONE batched kernel call for all
        boundaries of all functions; otherwise a per-boundary Python loop.
        Both orders are bit-identical (golden-pinned).

        Args:
          latencies, valid: per-boundary (F, W) windows, one entry per
            non-terminal tier (tier b feeds boundary b).
          queue_ages: per-boundary, per-function in-flight ages (or None
            per boundary).  In the live runtime these are tier b's own
            gateway backlog ages; in the simulator, tier b's queue — the
            per-tier signal that lets an *intermediate* boundary fire
            before its slow completions drain out.
          arrivals: per-function request counts this interval — either
            one flat sequence shared by every boundary (ingress demand),
            or a per-boundary sequence of per-function counts (demand
            that actually crossed boundary b-1, for net-aware caps).

        Returns the (num_tiers-1, F) stack of R_t percentages.
        """
        if self.eq1 == "sketch":
            raise ValueError('eq1="sketch" loops are driven by '
                             "step_stream(), not step_tiers()")
        if len(latencies) != self.num_boundaries:
            raise ValueError(
                f"{self.num_boundaries} boundaries need {self.num_boundaries}"
                f" latency windows, got {len(latencies)}")
        if queue_ages is not None and len(queue_ages) != self.num_boundaries:
            raise ValueError(
                f"{self.num_boundaries} boundaries need {self.num_boundaries}"
                f" queue-age entries, got {len(queue_ages)}")
        per_b = self._per_boundary_rps(arrivals)
        if self.vectorized:
            self._step_vectorized(latencies, valid, queue_ages, per_b)
        else:
            for b in range(self.num_boundaries):
                qa = queue_ages[b] if queue_ages is not None else None
                self._step_boundary(b, latencies[b], valid[b], qa, per_b[b])
        self.steps += 1
        return self.R_all

    def step_stream(self, samples: Sequence, queue_ages: Optional[Sequence]
                    = None, arrivals: Optional[Sequence] = None
                    ) -> np.ndarray:
        """One *streaming* control interval (``eq1="sketch"`` loops only).

        Instead of (F, W) windows, each boundary contributes just the
        latency observations recorded since the last tick — e.g. from
        :meth:`repro.core.metrics.MetricsRegistry.drain_fresh` — and the
        whole fleet advances in one jitted sketch-ingest + Eqs (1)-(4)
        call (:func:`repro.core.offload.offload_update_rows_stream`).
        No window is built and nothing is sorted, so a tick is O(samples
        + F * buckets): sub-millisecond at F=4096 where the exact path's
        percentile sort alone costs tens of milliseconds.

        Args:
          samples: per-boundary ``(fn_ids, values)`` array pairs (or None
            for an idle boundary) of fresh latency observations.
          queue_ages: as in :meth:`step_tiers`; in-flight ages are
            subsampled by the shared :meth:`_sample_ages` rule and
            ingested as additional observations.
          arrivals: as in :meth:`step_tiers`.

        Returns the (num_tiers-1, F) stack of R_t percentages.
        """
        if self.eq1 != "sketch":
            raise ValueError('step_stream() requires eq1="sketch"')
        if len(samples) != self.num_boundaries:
            raise ValueError(
                f"{self.num_boundaries} boundaries need {self.num_boundaries}"
                f" sample sets, got {len(samples)}")
        F, B, P = self.num_functions, self.num_boundaries, self._P
        per_b = self._per_boundary_rps(arrivals)
        rows_parts: List[np.ndarray] = []
        vals_parts: List[np.ndarray] = []
        for b in range(B):
            if samples[b] is not None:
                ids, vals = samples[b]
                if len(ids):
                    rows_parts.append(
                        np.asarray(ids, np.int64) + b * F)
                    vals_parts.append(np.asarray(vals, np.float32))
            qa = queue_ages[b] if queue_ages is not None else None
            if qa is not None:
                for fn, ages in enumerate(qa):
                    sel = self._sample_ages(ages, self.window)
                    if sel:
                        rows_parts.append(
                            np.full(len(sel), b * F + fn, np.int64))
                        vals_parts.append(np.asarray(sel, np.float32))
        rows = (np.concatenate(rows_parts) if rows_parts
                else np.zeros(0, np.int64))
        vals = (np.concatenate(vals_parts) if vals_parts
                else np.zeros(0, np.float32))
        for b in range(B):
            if not self._seen[b] and rows.size:
                lo = b * F
                if np.any((rows >= lo) & (rows < lo + F)):
                    self._seen[b] = True
        # Pad the sample batch to a power-of-two bucket (shape-stable
        # compilations across ticks with varying sample counts).
        S = max(8, 1 << (max(int(rows.size), 1) - 1).bit_length())
        rows_p = np.zeros(S, np.int32)
        vals_p = np.zeros(S, np.float32)
        svalid = np.zeros(S, bool)
        rows_p[:rows.size] = rows
        vals_p[:vals.size] = vals
        svalid[:rows.size] = True
        if self._active_j is None or not np.array_equal(
                self._seen, self._seen_snap):
            active = np.zeros(P, bool)
            active[:B * F] = np.repeat(self._seen, F)
            self._active_j = jnp.asarray(active)
            self._seen_snap = self._seen.copy()
        rps = np.full(P, 1e-3, np.float32)
        for b in range(B):
            rps[b * F:(b + 1) * F] = per_b[b]
        link_x100, req_b, net_mask = self._net_row_arrays()
        self._vstate, self._hist, R = offload.offload_update_rows_stream_jit(
            self._vstate, self._hist, rows_p, vals_p, svalid,
            self._decay_j, self._active_j, link_x100, req_b,
            net_mask, rps, cfg=self._structural)
        self.R_all = np.array(R, np.float32)[:B * F].reshape(B, F)
        self.steps += 1
        return self.R_all

    def dist(self) -> np.ndarray:
        """The current (F, num_tiers) routing distribution."""
        return self.policy.tier_distribution(self.R_all, self.num_tiers)

    def route(self, key: jax.Array, fn_ids: np.ndarray) -> np.ndarray:
        """Split a queued batch by the ingress boundary's R_t (2-tier
        bool-mask path, True = deeper tier)."""
        return self.policy.route(key, self.R_all[0], fn_ids,
                                 self.num_functions)

    def route_tiers(self, key: jax.Array, fn_ids: np.ndarray) -> np.ndarray:
        """Assign a queued batch over all N tiers -> (B,) tier indices."""
        return self.policy.route_tiers(key, self.dist(), fn_ids,
                                       self.num_functions)

    def hedge(self, key: jax.Array, ages_s: np.ndarray, fn_ids: np.ndarray,
              latencies: np.ndarray, valid: np.ndarray) -> np.ndarray:
        return self.policy.hedge(key, ages_s, fn_ids, latencies, valid)
