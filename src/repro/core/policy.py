"""First-class traffic policies + the shared control loop.

The paper's offloading strategy (Eqs (1)-(4)) is one algorithm that must
govern *any* deployment of the platform.  Historically the repo had two
divergent, stringly-typed copies of the scrape-and-update cycle — one
inlined in :class:`repro.core.simulator.ContinuumSimulator`, one in the
live :class:`repro.serving.tiers.EdgeCloudContinuum`.  This module is the
single control plane both now consume:

  * :class:`Policy` — the protocol every traffic policy implements
    (``init_state / observe / update / route``), plus :meth:`Policy.parse`
    so the established shorthands (``0.0``..``100.0``, ``"auto"``,
    ``"auto+net"``, ``"auto+hedge"``, ``"auto+migrate"``) keep working
    everywhere.
  * Concrete policies wrapping the existing primitives:
      - :class:`StaticSplit`     — fixed percentage (paper Table 2 columns);
      - :class:`AutoOffload`     — the paper's Eqs (1)-(4) controller;
      - :class:`NetAwareOffload` — beyond-paper link-capacity cap (§4.2);
      - :class:`HedgedOffload`   — auto + p99 straggler hedging on top of
        :func:`repro.core.router.hedged_mask`;
      - :class:`MigratingOffload` — auto + live mid-stream migration of
        slot-resident requests once R_t crosses a threshold (the
        ``migrate`` modifier composes with ``net``/``hedge`` as well).
  * :class:`ControlLoop` — one scrape-and-update cycle: latency windows,
    in-flight queue-age mixing, demand RPS, policy update.  The simulator
    and the live continuum drive the *same* code, so their R_t
    trajectories on a shared trace are identical (pinned by tests).

Policies are control-plane objects (host-side numpy in/out); the heavy
math inside ``AutoOffload.update`` stays jitted.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload, router

PolicySpec = Union[float, int, str, "Policy"]


class Policy:
    """Protocol + shared plumbing for traffic policies.

    A policy answers two questions each control interval:
      * ``update``: given the scraped latency windows, what percentage R_t
        of each function's traffic goes cloud-ward?
      * ``route``:  given R_t, which of the queued requests cross?

    ``init_state``/``observe`` let stateful policies carry their own state
    pytree through the loop without the harness knowing its shape.
    """

    #: canonical shorthand (used by ``parse`` round-trips and logs)
    spec: str = "policy"
    #: lazily-built jitted routers (shared by all route*() calls)
    _route_jit = None
    _route_tiers_jit = None
    #: Mid-stream migration knob: when set, the live continuous scheduler
    #: migrates slot-resident requests down-chain whenever this
    #: boundary's R_t reaches the threshold (percent) — in addition to
    #: routing new arrivals.  ``None`` disables migration (the default;
    #: routing-only is the paper's behaviour).
    migrate_threshold: Optional[float] = None
    #: A row is a migration victim only if it still has at least this
    #: many tokens to generate — nearly-done rows are cheaper to finish
    #: in place than to ship.
    migrate_min_remaining: int = 2

    # -- state ------------------------------------------------------------
    def init_state(self, num_functions: int) -> Any:
        return None

    def initial_R(self, num_functions: int) -> np.ndarray:
        """R_t before the first update (Eq (4): R_t(0) = 0)."""
        return np.zeros(num_functions, np.float32)

    def observe(self, state: Any, latencies: np.ndarray,
                valid: np.ndarray) -> Any:
        """Optional scrape-time hook (e.g. feed a quantile sketch)."""
        return state

    # -- control ----------------------------------------------------------
    def update(self, state: Any, latencies: np.ndarray, valid: np.ndarray,
               demand_rps: np.ndarray) -> Tuple[Any, np.ndarray]:
        """One controller step -> (new_state, (F,) percentages)."""
        raise NotImplementedError

    def route(self, key: jax.Array, R: np.ndarray, fn_ids: np.ndarray,
              num_functions: int) -> np.ndarray:
        """Split a batch by R_t -> (B,) bool mask, True = cloud.

        The batch is padded to a power-of-two bucket under one jitted
        ``route_batch`` (padding rows carry a void function id with pct 0),
        so live ticks with ever-changing queue depths reuse a handful of
        compiled shapes instead of recompiling the sort every tick.
        """
        B = len(fn_ids)
        if B == 0:
            return np.zeros(0, bool)
        if self._route_jit is None:
            self._route_jit = jax.jit(router.route_batch,
                                      static_argnums=(3,))
        Bp = max(1, 1 << (B - 1).bit_length())
        ids = np.full(Bp, num_functions, np.int32)
        ids[:B] = fn_ids
        pct = np.zeros(num_functions + 1, np.float32)
        pct[:num_functions] = R
        mask = self._route_jit(key, jnp.asarray(pct), jnp.asarray(ids),
                               num_functions + 1)
        return np.asarray(mask)[:B]

    def tier_distribution(self, R_all: np.ndarray,
                          num_tiers: int) -> np.ndarray:
        """Compose per-boundary percentages into a tier distribution.

        ``R_all`` is (num_tiers-1, F): boundary b's R_t is the percentage
        of the traffic *reaching* tier b that continues to tier b+1 (the
        waterfall reading of the paper's single edge->cloud R_t).  Returns
        (F, num_tiers) percentages summing to 100; for two tiers this is
        exactly ``[100 - R, R]``.
        """
        R_all = np.asarray(R_all, np.float32)
        F = R_all.shape[1]
        d = np.zeros((F, num_tiers), np.float32)
        remain = np.full(F, 100.0, np.float32)
        for b in range(num_tiers - 1):
            d[:, b] = remain * (100.0 - R_all[b]) / 100.0
            remain = remain * R_all[b] / 100.0
        d[:, num_tiers - 1] = remain
        return d

    def route_tiers(self, key: jax.Array, dist: np.ndarray,
                    fn_ids: np.ndarray, num_functions: int) -> np.ndarray:
        """Assign a batch over N tiers by the (F, N) distribution.

        Returns (B,) int tier indices.  Batches are padded to a
        power-of-two bucket (padding rows carry a void function id that
        routes 100% to tier 0) so live ticks reuse compiled shapes.
        """
        B = len(fn_ids)
        num_tiers = dist.shape[1]
        if B == 0:
            return np.zeros(0, np.int32)
        if num_tiers == 1:
            return np.zeros(B, np.int32)
        if self._route_tiers_jit is None:
            self._route_tiers_jit = jax.jit(router.route_tiers)
        Bp = max(1, 1 << (B - 1).bit_length())
        ids = np.full(Bp, num_functions, np.int32)
        ids[:B] = fn_ids
        distp = np.zeros((num_functions + 1, num_tiers), np.float32)
        distp[:num_functions] = dist
        distp[num_functions, 0] = 100.0
        tiers = self._route_tiers_jit(key, jnp.asarray(distp),
                                      jnp.asarray(ids))
        return np.asarray(tiers)[:B]

    def hedge(self, key: jax.Array, ages_s: np.ndarray, fn_ids: np.ndarray,
              latencies: np.ndarray, valid: np.ndarray) -> np.ndarray:
        """Which waiting requests deserve a backup on the other tier."""
        return np.zeros(len(fn_ids), bool)

    # -- parsing ----------------------------------------------------------
    @staticmethod
    def parse(spec: PolicySpec,
              offload_cfg: Optional[offload.OffloadConfig] = None,
              link_bytes_per_s: Optional[float] = None,
              req_bytes: Optional[float] = None) -> "Policy":
        """Turn the established shorthands into Policy objects.

        ``0.0``..``100.0`` (number or numeric string) -> StaticSplit;
        ``"auto"`` -> AutoOffload; ``"auto+net"`` -> NetAwareOffload;
        ``"auto+hedge"`` -> HedgedOffload.  Policy instances pass through
        untouched, so callers can accept "policy-or-shorthand" uniformly.
        """
        if isinstance(spec, Policy):
            return spec
        cfg = offload_cfg or offload.OffloadConfig()
        if isinstance(spec, (int, float)):
            return StaticSplit(float(spec))
        if isinstance(spec, str):
            s = spec.strip().lower()
            try:
                return StaticSplit(float(s))
            except ValueError:
                pass
            parts = s.split("+")
            mods = set(parts[1:])
            if parts[0] == "auto" and mods <= {"net", "hedge", "migrate"}:
                if "net" in mods:
                    net = NetAwareOffload(cfg,
                                          link_bytes_per_s=link_bytes_per_s,
                                          req_bytes=req_bytes)
                    pol = HedgedOffload(net.cfg) if "hedge" in mods else net
                elif "hedge" in mods:
                    pol = HedgedOffload(cfg)
                elif "migrate" in mods:
                    pol = MigratingOffload(cfg)
                else:
                    pol = AutoOffload(cfg)
                if "migrate" in mods and pol.migrate_threshold is None:
                    # the modifier composes with net/hedge variants too
                    pol.migrate_threshold = MigratingOffload.default_threshold
                pol.spec = "auto" + "".join(
                    "+" + m for m in ("net", "hedge", "migrate")
                    if m in mods)
                return pol
        raise ValueError(f"unknown policy spec {spec!r}")


class StaticSplit(Policy):
    """Fixed percentage of traffic to the cloud (the 0/25/50/75/100 columns
    of the paper's Table 2)."""

    def __init__(self, pct: float):
        if not 0.0 <= pct <= 100.0:
            raise ValueError(f"static split must be in [0, 100], got {pct}")
        self.pct = float(pct)
        self.spec = str(self.pct)

    def initial_R(self, num_functions: int) -> np.ndarray:
        return np.full(num_functions, self.pct, np.float32)

    def update(self, state, latencies, valid, demand_rps):
        return state, np.full(latencies.shape[0], self.pct, np.float32)


class AutoOffload(Policy):
    """The paper's adaptive controller: Eqs (1)-(4) on edge latency windows."""

    spec = "auto"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None):
        self.cfg = cfg or offload.OffloadConfig()
        self._update = jax.jit(
            lambda s, lat, v, rps: offload.offload_update(
                s, lat, self.cfg, valid=v, demand_rps=rps))

    def init_state(self, num_functions: int) -> offload.OffloadState:
        return offload.OffloadState.init(num_functions, self.cfg)

    def update(self, state, latencies, valid, demand_rps):
        state, R = self._update(state, latencies, valid,
                                np.asarray(demand_rps, np.float32))
        return state, np.asarray(R, np.float32)

    def set_link_capacity(self, link_bytes_per_s: float) -> bool:
        """Re-cap a net-aware controller against a changed link (fault
        injection: brownout/partition shrinks the capacity, recovery
        restores it).

        The jitted update closes over ``self.cfg`` at trace time, so
        mutating the dataclass alone would be silently ignored — the
        closure must be rebuilt.  Controller *state* (the boundary's
        OffloadState, held by the ControlLoop) is untouched: only the
        capacity the next Eq-(4) cap divides by changes.  No-op (False)
        for non-net-aware configs, whose updates never read the link.
        """
        if not self.cfg.net_aware:
            return False
        self.cfg = dataclasses.replace(
            self.cfg, link_bytes_per_s=float(link_bytes_per_s))
        # lint: ignore[recompile-hazard] -- deliberate: a capacity change
        # MUST rebuild the wrapper (cfg is closure-captured); fault events
        # are rare, so one recompile per event is the intended cost
        self._update = jax.jit(
            lambda s, lat, v, rps: offload.offload_update(
                s, lat, self.cfg, valid=v, demand_rps=rps))
        return True


class NetAwareOffload(AutoOffload):
    """Beyond-paper §4.2 extension: cap the offloaded fraction by what the
    edge->cloud link can absorb at the current demand."""

    spec = "auto+net"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 link_bytes_per_s: Optional[float] = None,
                 req_bytes: Optional[float] = None):
        cfg = cfg or offload.OffloadConfig()
        repl: Dict[str, Any] = {"net_aware": True}
        if link_bytes_per_s is not None:
            repl["link_bytes_per_s"] = link_bytes_per_s
        if req_bytes is not None:
            repl["req_bytes"] = req_bytes
        super().__init__(dataclasses.replace(cfg, **repl))


class HedgedOffload(AutoOffload):
    """Auto controller + request-level straggler mitigation: a queued
    request whose age already exceeds its function's p99 gets a backup
    issued on the other tier (``router.hedged_mask``)."""

    spec = "auto+hedge"

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 hedge_quantile: float = 0.99):
        super().__init__(cfg)
        self.hedge_quantile = float(hedge_quantile)

    def hedge(self, key, ages_s, fn_ids, latencies, valid):
        if len(fn_ids) == 0:
            return np.zeros(0, bool)
        p = self._tail_estimate(latencies, valid)
        return np.asarray(router.hedged_mask(
            key, jnp.asarray(ages_s, jnp.float32), jnp.asarray(p),
            jnp.asarray(fn_ids, jnp.int32)))

    def _tail_estimate(self, latencies, valid) -> np.ndarray:
        """(F,) per-function tail latency; +inf where nothing was observed
        yet (never hedge blind)."""
        lat = np.where(valid, np.asarray(latencies, np.float32), np.nan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)  # all-NaN rows
            p = np.nanpercentile(lat, self.hedge_quantile * 100.0, axis=-1)
        return np.where(np.isfinite(p), p, np.inf).astype(np.float32)


class MigratingOffload(AutoOffload):
    """Auto controller + live mid-stream migration (``"auto+migrate"``).

    Routing alone only redirects *new arrivals*: once a request is
    admitted into a tier's continuous-batching slots it is pinned there,
    so a burst of long decodes holds the slots hostage while R_t
    uselessly diverts fresh traffic.  With this variant, whenever a
    boundary's R_t reaches ``migrate_threshold`` the live scheduler also
    selects ``ceil(eligible * R_t / 100)`` slot-resident victims
    (longest-remaining first), ships their KV/state rows over the
    boundary's link (real cache bytes + token tail on the request's
    latency clock) and resumes them down-chain without re-prefill.  A
    landing that finds the destination full is *aborted*: the row
    resumes at its source, never lost.
    """

    spec = "auto+migrate"
    default_threshold = 50.0

    def __init__(self, cfg: Optional[offload.OffloadConfig] = None,
                 migrate_threshold: float = default_threshold,
                 migrate_min_remaining: int = 2):
        super().__init__(cfg)
        self.migrate_threshold = float(migrate_threshold)
        self.migrate_min_remaining = int(migrate_min_remaining)


class ControlLoop:
    """The shared scrape-and-update cycle (one per deployment).

    Each :meth:`step` is exactly what the paper's controller does once per
    Prometheus scrape: read the per-function latency windows, mix in the
    ages of *in-flight* queued requests (Knative's queue-proxy exposes
    queue depth/age gauges — the ages are what let Eq (1) fire during
    onset, before slow completions drain out), derive demand RPS, and ask
    the policy for fresh R_t percentages.

    Over an N-tier :class:`~repro.core.topology.Topology`, the loop keeps
    one controller *boundary* between each pair of adjacent tiers
    (``num_tiers - 1`` of them).  Boundary b is driven by tier b's latency
    windows and yields R_t[b] — the percentage of tier b's load to push
    down the chain (waterfall offloading).  The classic two-tier continuum
    is the single-boundary special case; :meth:`step` remains its
    unchanged (bit-identical) code path.

    Both :class:`~repro.core.simulator.ContinuumSimulator` and the live
    :class:`~repro.serving.tiers.EdgeCloudContinuum` drive this object, so
    a shared latency trace yields bit-identical R_t trajectories.
    """

    def __init__(self, policy: PolicySpec, num_functions: int,
                 window: int = 64, control_interval_s: float = 1.0,
                 num_tiers: int = 2,
                 boundary_policies: Optional[Sequence[PolicySpec]] = None):
        if num_tiers < 1:
            raise ValueError(f"num_tiers must be >= 1, got {num_tiers}")
        self.num_functions = num_functions
        self.window = window
        self.control_interval_s = control_interval_s
        self.num_tiers = int(num_tiers)
        self.num_boundaries = max(self.num_tiers - 1, 1)
        if boundary_policies is None:
            self.policy = Policy.parse(policy)
            self.policies = [self.policy] * self.num_boundaries
        else:
            # Per-boundary policy objects (e.g. auto+net with each
            # boundary's own link capacity); boundary 0 is canonical for
            # routing/hedging.
            if len(boundary_policies) != self.num_boundaries:
                raise ValueError(
                    f"{self.num_boundaries} boundaries need "
                    f"{self.num_boundaries} policies, "
                    f"got {len(boundary_policies)}")
            self.policies = [Policy.parse(p) for p in boundary_policies]
            self.policy = self.policies[0]
        self.states = [self.policies[b].init_state(num_functions)
                       for b in range(self.num_boundaries)]
        self.R_all = np.stack([self.policies[b].initial_R(num_functions)
                               for b in range(self.num_boundaries)])
        self.steps = 0

    # 2-tier compatibility views: the ingress boundary's state and R_t.
    @property
    def state(self):
        return self.states[0]

    @state.setter
    def state(self, v):
        self.states[0] = v

    @property
    def R(self) -> np.ndarray:
        return self.R_all[0]

    @R.setter
    def R(self, v):
        self.R_all[0] = np.asarray(v, np.float32)

    @staticmethod
    def mix_queue_ages(lat: np.ndarray, valid: np.ndarray, fn: int,
                       ages: Sequence[float], window: int) -> None:
        """Displace the oldest completions of function ``fn`` with a spread
        of in-flight queue ages (in place).

        Sampling is even across the queue: the age spread (new arrivals vs
        head-of-line) is the bimodality Eq (1) keys on.  Ages overwrite the
        *oldest* window entries so fresh queue state dominates stale (often
        timeout-censored) history.
        """
        k = min(len(ages), window // 2)
        sel = [ages[int(i * len(ages) / k)] for i in range(k)] if k else []
        if sel:
            lat[fn, :len(sel)] = sel
            valid[fn, :len(sel)] = True

    def _rps(self, arrivals: Optional[Sequence[float]]) -> np.ndarray:
        if arrivals is None:
            arrivals = [0.0] * self.num_functions
        return np.asarray(
            [max(a / self.control_interval_s, 1e-3) for a in arrivals],
            np.float32)

    def _step_boundary(self, b: int, latencies: np.ndarray,
                       valid: np.ndarray,
                       queue_ages: Optional[Sequence[Sequence[float]]],
                       rps: np.ndarray) -> np.ndarray:
        pol = self.policies[b]
        lat = np.array(latencies, np.float32, copy=True)
        val = np.array(valid, bool, copy=True)
        if queue_ages is not None:
            for fn, ages in enumerate(queue_ages):
                if ages:
                    self.mix_queue_ages(lat, val, fn, ages, self.window)
        self.states[b] = pol.observe(self.states[b], lat, val)
        if val.any():
            self.states[b], R = pol.update(self.states[b], lat, val, rps)
            self.R_all[b] = np.asarray(R, np.float32)
        return self.R_all[b]

    def step(self, latencies: np.ndarray, valid: np.ndarray,
             queue_ages: Optional[Sequence[Sequence[float]]] = None,
             arrivals: Optional[Sequence[float]] = None) -> np.ndarray:
        """One control interval on the ingress boundary -> (F,) R_t.

        Args:
          latencies, valid: (F, W) scraped windows (oldest entry first).
          queue_ages: per-function ages (seconds) of requests still
            waiting at the gateway, head-of-line first.
          arrivals: per-function request count seen this interval.
        """
        rps = self._rps(arrivals)
        out = self._step_boundary(0, latencies, valid, queue_ages, rps)
        self.steps += 1
        return out

    def step_tiers(self, latencies: Sequence[np.ndarray],
                   valid: Sequence[np.ndarray],
                   queue_ages: Optional[Sequence] = None,
                   arrivals: Optional[Sequence[float]] = None) -> np.ndarray:
        """One control interval over every boundary of the chain.

        Args:
          latencies, valid: per-boundary (F, W) windows, one entry per
            non-terminal tier (tier b feeds boundary b).
          queue_ages: per-boundary, per-function in-flight ages (or None
            per boundary).  In the live runtime these are tier b's own
            gateway backlog ages; in the simulator, tier b's queue — the
            per-tier signal that lets an *intermediate* boundary fire
            before its slow completions drain out.
          arrivals: per-function request counts this interval — either
            one flat sequence shared by every boundary (ingress demand),
            or a per-boundary sequence of per-function counts (demand
            that actually crossed boundary b-1, for net-aware caps).

        Returns the (num_tiers-1, F) stack of R_t percentages.
        """
        if len(latencies) != self.num_boundaries:
            raise ValueError(
                f"{self.num_boundaries} boundaries need {self.num_boundaries}"
                f" latency windows, got {len(latencies)}")
        if queue_ages is not None and len(queue_ages) != self.num_boundaries:
            raise ValueError(
                f"{self.num_boundaries} boundaries need {self.num_boundaries}"
                f" queue-age entries, got {len(queue_ages)}")
        if (arrivals is not None and len(arrivals)
                and isinstance(arrivals[0], (list, tuple, np.ndarray))):
            if len(arrivals) != self.num_boundaries:
                raise ValueError(
                    f"{self.num_boundaries} boundaries need "
                    f"{self.num_boundaries} arrival counts, "
                    f"got {len(arrivals)}")
            per_b = [self._rps(a) for a in arrivals]
        else:
            per_b = [self._rps(arrivals)] * self.num_boundaries
        for b in range(self.num_boundaries):
            qa = queue_ages[b] if queue_ages is not None else None
            self._step_boundary(b, latencies[b], valid[b], qa, per_b[b])
        self.steps += 1
        return self.R_all

    def dist(self) -> np.ndarray:
        """The current (F, num_tiers) routing distribution."""
        return self.policy.tier_distribution(self.R_all, self.num_tiers)

    def route(self, key: jax.Array, fn_ids: np.ndarray) -> np.ndarray:
        """Split a queued batch by the ingress boundary's R_t (2-tier
        bool-mask path, True = deeper tier)."""
        return self.policy.route(key, self.R_all[0], fn_ids,
                                 self.num_functions)

    def route_tiers(self, key: jax.Array, fn_ids: np.ndarray) -> np.ndarray:
        """Assign a queued batch over all N tiers -> (B,) tier indices."""
        return self.policy.route_tiers(key, self.dist(), fn_ids,
                                       self.num_functions)

    def hedge(self, key: jax.Array, ages_s: np.ndarray, fn_ids: np.ndarray,
              latencies: np.ndarray, valid: np.ndarray) -> np.ndarray:
        return self.policy.hedge(key, ages_s, fn_ids, latencies, valid)
