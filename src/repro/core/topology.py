"""Declarative N-tier continuum topologies.

The paper's platform is an edge-cloud *continuum*, but real hybrid
serverless deployments span device -> edge -> regional -> cloud chains
with heterogeneous links (Castro et al. 2022; Batool et al. 2025).  This
module is the single description both deployments of the platform consume:

  * :class:`TierSpec`  — one serving location: name, concurrent slots,
    context budget, autoscaling bounds, and (for the simulator) a
    service-rate multiplier plus a bounded queue depth.
  * :class:`LinkSpec`  — the hop between adjacent tiers: RTT and a
    bandwidth cap that cloud-ward requests serialize over.
  * :class:`Topology`  — an ordered chain of N tiers joined by N-1 links,
    with ingress at tier 0.  ``waterfall=True`` lets a tier spill its
    overflow down the chain instead of rejecting (each tier offloads its
    excess to the next — the N-tier generalization of the paper's single
    edge->cloud offload decision).

The historical two-tier API (``Continuum(edge=..., cloud=...)``) is sugar
over :meth:`Topology.pair`, which builds a 2-tier chain with waterfall
*disabled* so the seed semantics (queue-proxy overflow 503s feed Eq (1)'s
bimodality) — and hence the R_t trajectories — are preserved exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

from repro.core.replication import AutoscalingPolicy


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One serving location in the chain.

    ``slots``/``max_len``/``autoscaling``/window fields drive the live
    runtime (a tier is an :class:`~repro.serving.tiers.Tier` of Endpoint
    pools); ``service_rate_mult``/``queue_depth_per_slot`` drive the
    simulator:

      * ``service_rate_mult`` — service speed relative to the workload
        profile's *edge* service time (``mean = edge_service_s / mult``;
        a device tier at 0.5 is twice as slow as the edge).  ``None``
        means "profile default for this position": the ingress tier runs
        at the profile's edge speed, the deepest tier at the profile's
        cloud speed, and intermediate tiers interpolate geometrically.
      * ``queue_depth_per_slot`` — bounded per-slot request queue
        (Knative queue-proxy semantics); ``None`` = unbounded (the
        elastic cloud).  Both deployments honor it: the simulator bounds
        each ``_SimTier`` queue, the live runtime bounds each tier's
        :class:`~repro.serving.tiers.Gateway` backlog at
        ``slots * queue_depth_per_slot``.

    ``page_size`` switches this tier's endpoints to the paged KV pool
    (``repro.cache``): admission is then bounded by free *pages* —
    memory actually reserved — not slot count alone.  ``pool_pages``
    sizes the pool (default ``slots * max_len/page_size``: the same
    bytes a dense pool of ``slots`` rows holds).  Both deployments honor
    it: the live tier's endpoints reserve page tables, the simulator's
    per-tier capacity model tracks the same page ledger.

    ``model`` opts the tier into the **cost model**
    (:mod:`repro.launch.tier_cost`): name a zoo architecture (e.g.
    ``"llama3-405b"``) and, optionally, a ``mesh_shape`` — the
    ``(data, model)`` device mesh a sharded endpoint decodes over.
    A cost-modeled spec must NOT hand-set ``service_rate_mult``; instead
    :meth:`Topology.resolve_costs` derives ``slots`` (KV rows that fit
    next to the sharded weights in HBM), ``decode_step_ms`` (roofline
    of a tensor-parallel decode step) and ``service_rate_mult``
    (relative to the chain's first cost-modeled tier) from one
    ``hlo_cost`` pricing shared by the simulator and the live runtime.
    ``decode_step_ms`` is an output of that resolution, never an input:
    a spec with ``model`` set is *unresolved* until both
    ``decode_step_ms`` and ``service_rate_mult`` are populated, and
    both deployments refuse to run an unresolved spec.
    """

    name: str
    slots: int = 4
    max_len: int = 256
    # synthetic per-request overhead paid at this tier (e.g. WAN RTT)
    extra_latency_s: float = 0.0
    # per-tier KPA bounds; when set they override each function's spec on
    # this tier (e.g. pin an intermediate tier to zero with max_scale=0)
    autoscaling: Optional[AutoscalingPolicy] = None
    stable_window_s: float = 60.0
    panic_window_s: float = 6.0
    # --- paged KV pool (None = dense per-slot rows) ---------------------
    page_size: Optional[int] = None
    pool_pages: Optional[int] = None
    # --- simulator-only knobs -------------------------------------------
    service_rate_mult: Optional[float] = None
    queue_depth_per_slot: Optional[int] = 8
    # --- cost model (None = hand-set capacity/rates) --------------------
    model: Optional[str] = None
    mesh_shape: Optional[Tuple[int, int]] = None
    decode_step_ms: Optional[float] = None

    def __post_init__(self):
        if self.mesh_shape is not None:
            if self.model is None:
                raise ValueError("mesh_shape requires model")
            if (len(self.mesh_shape) != 2
                    or any(int(a) <= 0 for a in self.mesh_shape)):
                raise ValueError(
                    f"mesh_shape must be two positive (data, model) dims, "
                    f"got {self.mesh_shape}")
        if self.decode_step_ms is not None:
            if self.model is None:
                raise ValueError("decode_step_ms requires model (it is an "
                                 "output of cost resolution, not an input)")
            if self.decode_step_ms <= 0:
                raise ValueError(
                    f"tier {self.name!r}: decode_step_ms must be > 0")
        if self.model is not None:
            # Resolution is atomic: a cost-modeled spec either has both
            # derived fields (resolved) or neither (unresolved).  A
            # hand-set service_rate_mult on a cost-modeled tier is the
            # drift this PR removes — reject it outright.
            if (self.service_rate_mult is None) != (self.decode_step_ms
                                                    is None):
                raise ValueError(
                    f"tier {self.name!r}: cost-modeled specs derive "
                    f"service_rate_mult and decode_step_ms together via "
                    f"Topology.resolve_costs(); set neither by hand")
        if self.page_size is not None:
            if self.page_size <= 0 or self.max_len % self.page_size:
                raise ValueError(
                    f"page_size must divide max_len ({self.max_len}), "
                    f"got {self.page_size}")
            ppr = self.max_len // self.page_size
            if self.pool_pages is not None and self.pool_pages < ppr:
                raise ValueError(
                    f"pool_pages={self.pool_pages} cannot hold one full "
                    f"row ({ppr} pages)")
        elif self.pool_pages is not None:
            raise ValueError("pool_pages requires page_size")

    @property
    def pages_per_row(self) -> int:
        return 0 if self.page_size is None else self.max_len // self.page_size

    @property
    def total_pages(self) -> int:
        """Usable pool pages (0 for dense tiers)."""
        if self.page_size is None:
            return 0
        if self.pool_pages is not None:
            return self.pool_pages
        return self.slots * self.pages_per_row

    @property
    def cost_modeled(self) -> bool:
        """True when capacity/rates come from the cost model."""
        return self.model is not None

    @property
    def resolved(self) -> bool:
        """True when this spec is runnable: hand-set, or cost-derived."""
        return self.model is None or self.decode_step_ms is not None

    @property
    def devices(self) -> int:
        """Devices this tier's endpoint spans (mesh product; 1 dense)."""
        if self.mesh_shape is None:
            return 1
        return int(self.mesh_shape[0]) * int(self.mesh_shape[1])


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """The hop between tier i and tier i+1 (FIFO pipe model: transfers
    serialize; saturation shows up as the link running ahead of time)."""

    rtt_s: float = 0.04
    bandwidth_Bps: float = 100e6

    def latency_s(self, nbytes: float = 0.0) -> float:
        """Wall-clock cost of moving one ``nbytes`` payload over the hop
        (RTT + serialization).  The live runtime charges this to a request
        whenever it crosses the link (routing or waterfall spill)."""
        return self.rtt_s + nbytes / self.bandwidth_Bps


class Topology:
    """An ordered chain of N tiers joined by N-1 links, ingress at tier 0.

    The one declarative shape both deployments consume: the simulator
    builds its event loop from it and the live runtime builds real
    endpoint pools per tier.  The controller runs one boundary per
    adjacent tier pair — boundary ``b`` is driven by tier ``b``'s
    signals and yields ``R_t[b]``, the percentage of tier ``b``'s load
    pushed down the chain (see docs/architecture.md).

    ``waterfall=True`` spills a stalled tier's overflow to the next
    tier instead of rejecting; construction validates the chain
    (non-empty, unique tier names, ``len(links) == len(tiers) - 1``,
    non-negative RTTs/queues/slots).
    """

    def __init__(self, tiers: Sequence[TierSpec],
                 links: Optional[Sequence[LinkSpec]] = None,
                 waterfall: bool = True):
        tiers = tuple(tiers)
        if not tiers:
            raise ValueError("topology needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in {names}")
        for t in tiers:
            if not isinstance(t, TierSpec):
                raise TypeError(f"expected TierSpec, got {type(t).__name__}")
            if t.slots < 0:
                raise ValueError(f"tier {t.name!r}: negative slots")
            if t.service_rate_mult is not None and t.service_rate_mult <= 0:
                raise ValueError(
                    f"tier {t.name!r}: service_rate_mult must be > 0")
            if (t.queue_depth_per_slot is not None
                    and t.queue_depth_per_slot < 0):
                raise ValueError(
                    f"tier {t.name!r}: negative queue_depth_per_slot")
        if links is None:
            links = tuple(LinkSpec() for _ in tiers[1:])
        links = tuple(links)
        if len(links) != len(tiers) - 1:
            raise ValueError(
                f"{len(tiers)} tiers need {len(tiers) - 1} links, "
                f"got {len(links)}")
        for i, l in enumerate(links):
            if l.rtt_s < 0:
                raise ValueError(f"link {i}: negative RTT")
            if l.bandwidth_Bps <= 0:
                raise ValueError(f"link {i}: bandwidth must be > 0")
        self.tiers: Tuple[TierSpec, ...] = tiers
        self.links: Tuple[LinkSpec, ...] = links
        self.waterfall = bool(waterfall)

    # -- chain protocol ----------------------------------------------------
    @property
    def num_tiers(self) -> int:
        return len(self.tiers)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def index(self, name: str) -> int:
        return self.names.index(name)

    def __len__(self) -> int:
        return len(self.tiers)

    def __iter__(self) -> Iterator[TierSpec]:
        return iter(self.tiers)

    def __repr__(self) -> str:
        chain = " -> ".join(self.names)
        return (f"Topology({chain}, waterfall={self.waterfall})")

    # -- cost resolution ---------------------------------------------------
    def resolve_costs(self) -> "Topology":
        """Resolve every cost-modeled tier against the hardware cost model.

        Specs that name a ``model`` get derived ``slots`` /
        ``decode_step_ms`` / ``service_rate_mult`` from
        :func:`repro.launch.tier_cost.resolve_specs` (one ``hlo_cost``
        roofline pricing shared with the live engine); hand-set specs —
        including :meth:`pair`'s elastic cloud with its
        ``service_rate_mult=None`` profile-default sentinel — pass
        through bit-identically.  Returns ``self`` when nothing needs
        resolving, else a new resolved :class:`Topology`.
        """
        if all(t.resolved for t in self.tiers):
            return self
        from repro.launch import tier_cost  # deferred: jax-heavy import
        return type(self)(tier_cost.resolve_specs(self.tiers),
                          links=self.links, waterfall=self.waterfall)

    @classmethod
    def costed(cls, tiers: Sequence[TierSpec],
               links: Optional[Sequence[LinkSpec]] = None,
               waterfall: bool = True) -> "Topology":
        """Build a chain and resolve its cost-modeled tiers in one step."""
        return cls(tiers, links=links, waterfall=waterfall).resolve_costs()

    # -- constructors ------------------------------------------------------
    @classmethod
    def pair(cls, edge, cloud, link: Optional[LinkSpec] = None) -> "Topology":
        """The historical two-tier continuum as a Topology.

        Accepts :class:`TierSpec` or the legacy ``TierConfig`` shape (any
        object with ``slots``/``max_len``/... attributes).  Waterfall is
        disabled: a full edge queue rejects (503) rather than spilling —
        the seed semantics Eq (1) keys on.  The default link carries zero
        RTT because the legacy API expresses the WAN hop as the cloud
        tier's ``extra_latency_s``; an explicit ``link`` opts into
        link-level accounting.  Queue bounds mirror the paper apparatus
        (``SimConfig.default_topology``): the edge's backlog is bounded
        (queue-proxy), the elastic cloud's is unbounded.
        """
        return cls(tiers=(_as_spec(edge, "edge"),
                          _as_spec(cloud, "cloud", queue_depth=None)),
                   links=(link or LinkSpec(rtt_s=0.0),), waterfall=False)

    @classmethod
    def device_edge_cloud(cls, device_slots: int = 2, edge_slots: int = 4,
                          cloud_slots: int = 64, max_len: int = 256,
                          autoscaling: Optional[AutoscalingPolicy] = None,
                          cost_model: bool = False) -> "Topology":
        """The canonical 3-tier example: on-device -> edge site -> cloud.

        With ``cost_model=False`` (the historical default) the device
        tier is hand-set to half the edge's speed behind a short LAN
        hop, and the elastic cloud runs at the profile default.

        With ``cost_model=True`` the chain is the honestly-sized
        continuum: stablelm-1.6b on the device, qwen2.5-14b on the edge
        site, llama3-405b shard_map-sharded over a (16, 16) cloud pod —
        and every ``slots`` / ``decode_step_ms`` / ``service_rate_mult``
        is derived from ``hlo_cost`` rooflines (requested slot counts
        become *ceilings*, clamped to what fits in HBM).  Note the
        honest speed inversion: each hop down the chain serves a far
        bigger model, so per-token service gets *slower* cloud-ward
        while quality and aggregate capacity rise.
        """
        if cost_model:
            return cls(
                tiers=(TierSpec("device", slots=device_slots,
                                max_len=max_len, autoscaling=autoscaling,
                                model="stablelm-1.6b", mesh_shape=(1, 1),
                                queue_depth_per_slot=4),
                       TierSpec("edge", slots=edge_slots, max_len=max_len,
                                autoscaling=autoscaling,
                                model="qwen2.5-14b", mesh_shape=(1, 2),
                                queue_depth_per_slot=8),
                       TierSpec("cloud", slots=cloud_slots, max_len=max_len,
                                autoscaling=autoscaling,
                                model="llama3-405b", mesh_shape=(16, 16),
                                queue_depth_per_slot=None)),
                links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
                       LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)),
                waterfall=True).resolve_costs()
        return cls(
            tiers=(TierSpec("device", slots=device_slots, max_len=max_len,
                            autoscaling=autoscaling,
                            service_rate_mult=0.5, queue_depth_per_slot=4),
                   TierSpec("edge", slots=edge_slots, max_len=max_len,
                            autoscaling=autoscaling,
                            service_rate_mult=1.0, queue_depth_per_slot=8),
                   TierSpec("cloud", slots=cloud_slots, max_len=max_len,
                            autoscaling=autoscaling,
                            service_rate_mult=None,
                            queue_depth_per_slot=None)),
            links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
                   LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)),
            waterfall=True)


def _as_spec(obj, name: str, queue_depth: Optional[int] = 8) -> TierSpec:
    """Coerce a TierSpec or legacy TierConfig-shaped object to a TierSpec.

    ``queue_depth`` supplies ``queue_depth_per_slot`` for legacy objects
    that don't carry the field (an explicit TierSpec keeps its own)."""
    if isinstance(obj, TierSpec):
        return obj
    return TierSpec(
        name=name,
        slots=obj.slots,
        max_len=obj.max_len,
        extra_latency_s=getattr(obj, "extra_latency_s", 0.0),
        autoscaling=getattr(obj, "autoscaling", None),
        stable_window_s=getattr(obj, "stable_window_s", 60.0),
        panic_window_s=getattr(obj, "panic_window_s", 6.0),
        queue_depth_per_slot=getattr(obj, "queue_depth_per_slot",
                                     queue_depth))
