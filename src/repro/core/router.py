"""Traffic splitting across the tiers of the continuum.

The paper's API gateway "makes the decision randomly, and only a percentage
of traffic (decided by the offloading strategy) is being sent to the cloud".
TPU serving is batched, so the router exposes:

  * ``route_bernoulli`` — the paper-faithful per-request coin flip;
  * ``route_batch``     — expectation-matched batch split (deterministic
    count = floor(B*p) plus a Bernoulli remainder), which has the same mean
    and strictly lower variance. This is the 2-tier production path.
  * ``route_tiers``     — the N-tier generalization: vectorized,
    expectation-matched categorical assignment of a batch over a
    per-function tier *distribution* (see ``repro.core.topology``).

All are pure jnp and run under jit.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def route_bernoulli(key: jax.Array, pct: jnp.ndarray, fn_ids: jnp.ndarray) -> jnp.ndarray:
    """Per-request i.i.d. routing (paper-faithful).

    Args:
      key: PRNG key.
      pct: (F,) percentage of traffic to offload per function.
      fn_ids: (B,) function id of each request in the batch.

    Returns:
      (B,) bool — True = send to cloud.
    """
    p = jnp.clip(pct[fn_ids] / 100.0, 0.0, 1.0)
    return jax.random.uniform(key, fn_ids.shape) < p


def route_batch(key: jax.Array, pct: jnp.ndarray, fn_ids: jnp.ndarray,
                num_functions: int) -> jnp.ndarray:
    """Expectation-matched split: per function, exactly ``round-ish(B_f * p_f)``
    requests go to the cloud (floor + Bernoulli(frac) extra).

    Within each function, requests are ranked by i.i.d. uniform noise and
    the ``n_cloud[f]`` lowest-ranked cross — computed with one lexsort plus
    a segmented cummax, O(B log B) (the naive (B, B) same-function rank
    matrix lives on as :func:`route_batch_dense` for the microbenchmark).

    Returns (B,) bool mask, True = cloud.
    """
    B = fn_ids.shape[0]
    p = jnp.clip(pct / 100.0, 0.0, 1.0)                       # (F,)
    per_fn = jnp.zeros(num_functions, jnp.float32).at[fn_ids].add(1.0)
    want = per_fn * p                                         # (F,) expected cloud
    base = jnp.floor(want)
    frac = want - base
    extra = (jax.random.uniform(key, (num_functions,)) < frac).astype(jnp.float32)
    n_cloud = base + extra                                    # (F,)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (B,))
    # Sort by (function, noise); a request's rank within its function is its
    # sorted position minus the start of its function's segment.
    order = jnp.lexsort((noise, fn_ids))
    sorted_fn = fn_ids[order]
    pos = jnp.arange(B, dtype=jnp.int32)
    seg_start = jnp.where(
        jnp.concatenate([jnp.ones(1, bool), sorted_fn[1:] != sorted_fn[:-1]]),
        pos, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank = jnp.zeros(B, jnp.int32).at[order].set(pos - seg_start)
    return rank < n_cloud[fn_ids]


def route_tiers(key: jax.Array, dist: jnp.ndarray,
                fn_ids: jnp.ndarray) -> jnp.ndarray:
    """Expectation-matched categorical assignment over N tiers.

    The N-tier generalization of :func:`route_batch`: per function, the
    number of requests sent to tier >= j is ``floor(B_f * T_j)`` plus a
    Bernoulli remainder, where ``T_j`` is the tail share of the
    distribution; within a function, requests are ranked by i.i.d. noise
    (one lexsort, O(B log B)) and the lowest-ranked cross deepest.  At
    N=2 this has the same marginals as :func:`route_batch`.

    Args:
      dist: (F, N) per-function percentage split over tiers (rows sum
        to 100; tier 0 = ingress).
      fn_ids: (B,) function id of each request.

    Returns:
      (B,) int32 — tier index per request.
    """
    B = fn_ids.shape[0]
    F, N = dist.shape
    p = jnp.clip(dist / 100.0, 0.0, 1.0)                      # (F, N)
    tail = jnp.cumsum(p[:, ::-1], axis=1)[:, ::-1]            # share to >= j
    per_fn = jnp.zeros(F, jnp.float32).at[fn_ids].add(1.0)
    want = per_fn[:, None] * tail                             # (F, N)
    base = jnp.floor(want)
    frac = want - base
    extra = (jax.random.uniform(key, (F, N)) < frac).astype(jnp.float32)
    n = base + extra
    n = n.at[:, 0].set(per_fn)                                # all reach tier 0
    # Independent Bernoullis can break monotonicity; clip to a staircase.
    n = jax.lax.associative_scan(jnp.minimum, n, axis=1)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (B,))
    order = jnp.lexsort((noise, fn_ids))
    sorted_fn = fn_ids[order]
    pos = jnp.arange(B, dtype=jnp.int32)
    seg_start = jnp.where(
        jnp.concatenate([jnp.ones(1, bool), sorted_fn[1:] != sorted_fn[:-1]]),
        pos, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank = jnp.zeros(B, jnp.int32).at[order].set(pos - seg_start)
    return jnp.sum(rank[:, None] < n[fn_ids, 1:], axis=1).astype(jnp.int32)


def route_batch_dense(key: jax.Array, pct: jnp.ndarray, fn_ids: jnp.ndarray,
                      num_functions: int) -> jnp.ndarray:
    """Reference O(B^2) implementation of :func:`route_batch` (same
    distribution; kept for equivalence tests and the controller
    microbenchmark)."""
    B = fn_ids.shape[0]
    p = jnp.clip(pct / 100.0, 0.0, 1.0)                       # (F,)
    onehot = jax.nn.one_hot(fn_ids, num_functions, dtype=jnp.float32)  # (B,F)
    per_fn = jnp.sum(onehot, axis=0)                          # (F,) counts
    want = per_fn * p                                         # (F,) expected cloud
    base = jnp.floor(want)
    frac = want - base
    extra = (jax.random.uniform(key, (num_functions,)) < frac).astype(jnp.float32)
    n_cloud = base + extra                                    # (F,)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (B,))
    # rank of request i among same-function requests
    same = onehot @ onehot.T                                  # (B,B) 1 if same fn
    rank = jnp.sum(same * (noise[None, :] < noise[:, None]), axis=1)
    return rank < n_cloud[fn_ids]


def split_counts(mask: jnp.ndarray, fn_ids: jnp.ndarray,
                 num_functions: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(F,) edge / cloud request counts from a routing mask (for metrics)."""
    onehot = jax.nn.one_hot(fn_ids, num_functions, dtype=jnp.int32)
    cloud = jnp.sum(onehot * mask[:, None].astype(jnp.int32), axis=0)
    total = jnp.sum(onehot, axis=0)
    return total - cloud, cloud


def hedged_mask(key: jax.Array, latencies: jnp.ndarray, p99: jnp.ndarray,
                fn_ids: jnp.ndarray) -> jnp.ndarray:
    """Beyond-paper: mark in-flight requests whose age already exceeds the
    function's p99 for duplication on the other tier (hedged request /
    backup request — request-level straggler mitigation).

    Args:
      latencies: (B,) current age of each in-flight request.
      p99: (F,) per-function p99 latency estimate.
    Returns:
      (B,) bool — True = issue a hedge.
    """
    del key  # deterministic rule; key kept for API symmetry
    return latencies > p99[fn_ids]
