"""Lightweight metrics registry — the Prometheus stand-in.

The paper deploys a Prometheus instance per edge cluster with short data
liveness, scraped by the offloading controller. Here each tier keeps ring
buffers of recent observations; the controller reads fixed-size latency
windows from them. Host-side (plain numpy) because this is scrape-cadence
control-plane data; the on-device path uses ``core.quantile.Histogram``.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


class LatencyWindow:
    """Fixed-capacity ring of recent request latencies for one function."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._buf: Deque[float] = collections.deque(maxlen=capacity)

    def record(self, latency_s: float) -> None:
        self._buf.append(float(latency_s))

    def clear(self) -> None:
        """Drop all recorded observations."""
        self._buf.clear()

    def values(self) -> np.ndarray:
        """All retained observations, oldest first (for percentile
        reports; the controller path uses :meth:`window`)."""
        return np.asarray(self._buf, np.float32)

    def window(self, size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Return (latencies, valid) padded/masked to ``size``."""
        data = list(self._buf)[-size:]
        lat = np.zeros(size, np.float32)
        valid = np.zeros(size, bool)
        if data:
            lat[: len(data)] = data
            valid[: len(data)] = True
        return lat, valid

    def __len__(self) -> int:
        return len(self._buf)


class MetricsRegistry:
    """Per-function latency windows + scalar gauges/counters."""

    def __init__(self, function_names: List[str], capacity: int = 256):
        self.function_names = list(function_names)
        self.latency: Dict[str, LatencyWindow] = {
            n: LatencyWindow(capacity) for n in self.function_names}
        self.counters: Dict[str, float] = collections.defaultdict(float)
        self.gauges: Dict[str, float] = {}

    def register(self, fn: str, capacity: int = 256) -> None:
        """Add a function after construction (dynamic deployments)."""
        if fn not in self.latency:
            self.function_names.append(fn)
            self.latency[fn] = LatencyWindow(capacity)

    def record_latency(self, fn: str, latency_s: float) -> None:
        self.latency[fn].record(latency_s)

    def clear(self) -> None:
        """Drop all recorded observations (e.g. after a warmup phase)."""
        for w in self.latency.values():
            w.clear()
        self.counters.clear()
        self.gauges.clear()

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def counter(self, name: str) -> float:
        """Read a counter without materializing it (``counters`` is a
        defaultdict — bare indexing would create zero-valued entries)."""
        return float(self.counters.get(name, 0.0))

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = v

    def latency_values(self, fn: Optional[str] = None) -> np.ndarray:
        """Retained latency observations for one function (or all of
        them concatenated) — the raw samples benchmark percentiles are
        computed from."""
        if fn is not None:
            return self.latency[fn].values()
        vals = [w.values() for w in self.latency.values()]
        return (np.concatenate(vals) if vals
                else np.zeros(0, np.float32))

    def latency_windows(self, size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked (F, size) latency windows + masks, function-ordered."""
        lats, valids = [], []
        for n in self.function_names:
            l, v = self.latency[n].window(size)
            lats.append(l)
            valids.append(v)
        return np.stack(lats), np.stack(valids)
