"""Lightweight metrics registry — the Prometheus stand-in.

The paper deploys a Prometheus instance per edge cluster with short data
liveness, scraped by the offloading controller. Here each tier keeps ring
buffers of recent observations; the controller reads fixed-size latency
windows from them. Host-side (plain numpy) because this is scrape-cadence
control-plane data; the on-device path uses ``core.quantile.Histogram``.

Storage is one stacked (F, capacity) float32 ring (:class:`VectorWindows`)
rather than F Python deques, so the controller's scrape —
:meth:`MetricsRegistry.latency_windows` — is a single vectorized gather
instead of an O(F) Python loop, and the streaming sketch path can drain
the fresh samples of *all* functions at once (:meth:`VectorWindows.drain_fresh`).
The per-function dict view (``registry.latency[name]``) is preserved as
row views over the shared store, bit-identical to the historical
deque-backed windows.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


class LatencyWindow:
    """Fixed-capacity ring of recent request latencies for one function.

    The standalone (deque-backed) form, kept as the reference semantics
    for :class:`VectorWindows` rows and for callers that track a single
    series outside a registry.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._buf: Deque[float] = collections.deque(maxlen=capacity)

    def record(self, latency_s: float) -> None:
        self._buf.append(float(latency_s))

    def clear(self) -> None:
        """Drop all recorded observations."""
        self._buf.clear()

    def values(self) -> np.ndarray:
        """All retained observations, oldest first (for percentile
        reports; the controller path uses :meth:`window`)."""
        return np.asarray(self._buf, np.float32)

    def window(self, size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Return (latencies, valid) padded/masked to ``size``."""
        data = list(self._buf)[-size:]
        lat = np.zeros(size, np.float32)
        valid = np.zeros(size, bool)
        if data:
            lat[: len(data)] = data
            valid[: len(data)] = True
        return lat, valid

    def __len__(self) -> int:
        return len(self._buf)


class VectorWindows:
    """Stacked per-function latency rings: one (F, capacity) float32 array.

    Row ``r`` behaves exactly like a ``LatencyWindow`` (same retention,
    same oldest-first window layout, bit-identical float32 contents); the
    win is that :meth:`windows` reads every function's window in one numpy
    gather — O(F*size) array work with no per-function Python — which is
    what lets one control tick scrape a 10k-function fleet.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._buf = np.zeros((0, self.capacity), np.float32)
        self._n = np.zeros(0, np.int64)          # total recorded per row
        # Append log since the last drain (streaming sketch ingest).
        self._fresh_rows: List[int] = []
        self._fresh_vals: List[float] = []

    @property
    def num_rows(self) -> int:
        return self._buf.shape[0]

    def add_row(self) -> int:
        """Append one function row; returns its index."""
        self._buf = np.vstack(
            [self._buf, np.zeros((1, self.capacity), np.float32)])
        self._n = np.append(self._n, 0)
        return self._buf.shape[0] - 1

    def record(self, row: int, latency_s: float) -> None:
        v = np.float32(latency_s)
        self._buf[row, self._n[row] % self.capacity] = v
        self._n[row] += 1
        self._fresh_rows.append(row)
        self._fresh_vals.append(float(v))

    def count(self, row: int) -> int:
        """Observations currently retained for ``row`` (deque ``len``)."""
        return int(min(self._n[row], self.capacity))

    def clear_row(self, row: int) -> None:
        self._n[row] = 0

    def clear(self) -> None:
        self._n[:] = 0
        self._fresh_rows.clear()
        self._fresh_vals.clear()

    def values(self, row: int) -> np.ndarray:
        """Retained observations of one row, oldest first."""
        k = self.count(row)
        idx = (self._n[row] - k + np.arange(k)) % self.capacity
        return self._buf[row, idx].astype(np.float32)

    def window(self, row: int, size: int) -> Tuple[np.ndarray, np.ndarray]:
        """(size,) window of one row — same layout as LatencyWindow."""
        lat, valid = self.windows(size, rows=np.asarray([row]))
        return lat[0], valid[0]

    def windows(self, size: int,
                rows: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked (F, size) windows + masks in one vectorized gather.

        Row r's window holds its last ``min(count, size)`` observations
        oldest-first at the start, zero-padded/False-masked after — the
        exact layout of :meth:`LatencyWindow.window`, for every function
        at once.
        """
        n = self._n if rows is None else self._n[rows]
        buf = self._buf if rows is None else self._buf[rows]
        k = np.minimum(np.minimum(n, self.capacity), size)   # (F,)
        j = np.arange(size)[None, :]                         # (1, size)
        idx = ((n - k)[:, None] + j) % self.capacity
        valid = j < k[:, None]
        lat = np.where(
            valid, np.take_along_axis(buf, idx, axis=1), np.float32(0.0))
        return lat.astype(np.float32), valid

    def drain_fresh(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, values) recorded since the last drain, then reset.

        The streaming controller's scrape: each control tick ingests only
        the new samples into the quantile sketch instead of re-reading
        whole windows.
        """
        rows = np.asarray(self._fresh_rows, np.int32)
        vals = np.asarray(self._fresh_vals, np.float32)
        self._fresh_rows.clear()
        self._fresh_vals.clear()
        return rows, vals


class _RowView:
    """LatencyWindow-compatible view of one VectorWindows row (what
    ``registry.latency[name]`` hands out)."""

    __slots__ = ("_vw", "_row")

    def __init__(self, vw: VectorWindows, row: int):
        self._vw = vw
        self._row = row

    @property
    def capacity(self) -> int:
        return self._vw.capacity

    def record(self, latency_s: float) -> None:
        self._vw.record(self._row, latency_s)

    def clear(self) -> None:
        self._vw.clear_row(self._row)

    def values(self) -> np.ndarray:
        return self._vw.values(self._row)

    def window(self, size: int) -> Tuple[np.ndarray, np.ndarray]:
        return self._vw.window(self._row, size)

    def __len__(self) -> int:
        return self._vw.count(self._row)


class MetricsRegistry:
    """Per-function latency windows + scalar gauges/counters.

    ``latency[name]`` keeps the historical per-function window API, but
    all rows share one :class:`VectorWindows` store so the controller
    scrape is a single stacked gather.
    """

    def __init__(self, function_names: List[str], capacity: int = 256):
        self.function_names = list(function_names)
        self.windows = VectorWindows(capacity)
        self.latency: Dict[str, _RowView] = {}
        for n in self.function_names:
            self.latency[n] = _RowView(self.windows, self.windows.add_row())
        self.counters: Dict[str, float] = collections.defaultdict(float)
        self.gauges: Dict[str, float] = {}

    def register(self, fn: str, capacity: int = 256) -> None:
        """Add a function after construction (dynamic deployments)."""
        if fn not in self.latency:
            self.function_names.append(fn)
            self.latency[fn] = _RowView(self.windows, self.windows.add_row())

    def record_latency(self, fn: str, latency_s: float) -> None:
        self.latency[fn].record(latency_s)

    def clear(self) -> None:
        """Drop all recorded observations (e.g. after a warmup phase)."""
        self.windows.clear()
        self.counters.clear()
        self.gauges.clear()

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] += v

    def counter(self, name: str) -> float:
        """Read a counter without materializing it (``counters`` is a
        defaultdict — bare indexing would create zero-valued entries)."""
        return float(self.counters.get(name, 0.0))

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = v

    def latency_values(self, fn: Optional[str] = None) -> np.ndarray:
        """Retained latency observations for one function (or all of
        them concatenated) — the raw samples benchmark percentiles are
        computed from."""
        if fn is not None:
            return self.latency[fn].values()
        vals = [w.values() for w in self.latency.values()]
        return (np.concatenate(vals) if vals
                else np.zeros(0, np.float32))

    def latency_windows(self, size: int) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked (F, size) latency windows + masks, function-ordered."""
        return self.windows.windows(size)

    def drain_fresh(self) -> Tuple[np.ndarray, np.ndarray]:
        """(fn_rows, values) recorded since the last drain — the
        streaming scrape for ``ControlLoop(eq1="sketch")``."""
        return self.windows.drain_fresh()
