"""Streaming latency quantiles — the on-device Prometheus analogue.

The paper scrapes request latencies into Prometheus and queries p95/p50.
On a TPU there is no sidecar; instead each serving tier maintains a
*decayed log-bucketed histogram* (exactly the shape of a Prometheus
histogram with exponential buckets) as a small on-device array, updated
inside the jitted serving step. Quantiles are read with the same
interpolation rule Prometheus' ``histogram_quantile`` uses (linear within
the bucket), done in log-space because the buckets are geometric.

Everything is pure jnp: update/read are O(num_buckets) and vectorizable
over the function axis F.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Histogram:
    """Decayed log-bucket histogram, vectorized over functions.

    Attributes:
      counts: (F, B) float32 decayed bucket counts.
      log_lo: scalar — log of the smallest bucket edge.
      log_hi: scalar — log of the largest bucket edge.
    """

    def __init__(self, counts, log_lo, log_hi):
        self.counts = counts
        self.log_lo = log_lo
        self.log_hi = log_hi

    @staticmethod
    def init(num_functions: int, num_buckets: int = 64,
             lo: float = 1e-4, hi: float = 1e3) -> "Histogram":
        return Histogram(
            counts=jnp.zeros((num_functions, num_buckets), jnp.float32),
            log_lo=jnp.float32(jnp.log(lo)),
            log_hi=jnp.float32(jnp.log(hi)),
        )

    # --- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.counts, self.log_lo, self.log_hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_buckets(self) -> int:
        return self.counts.shape[-1]


def _bucket_index(hist: Histogram, x: jnp.ndarray) -> jnp.ndarray:
    """Bucket of value x (clamped into range)."""
    B = hist.num_buckets
    t = (jnp.log(jnp.maximum(x, 1e-30)) - hist.log_lo) / (hist.log_hi - hist.log_lo)
    return jnp.clip((t * B).astype(jnp.int32), 0, B - 1)


def update(hist: Histogram, latencies: jnp.ndarray,
           valid: jnp.ndarray | None = None, decay: float = 0.9) -> Histogram:
    """Fold a (F, W) window of observations into the decayed histogram.

    ``decay`` plays the role of Prometheus' retention: old observations
    fade geometrically per update call (the paper configures "short data
    liveness" for the same reason).
    """
    lat = jnp.asarray(latencies, jnp.float32)
    idx = _bucket_index(hist, lat)                      # (F, W)
    w = jnp.ones_like(lat) if valid is None else valid.astype(jnp.float32)
    onehot = jax.nn.one_hot(idx, hist.num_buckets, dtype=jnp.float32)  # (F,W,B)
    fresh = jnp.einsum("fw,fwb->fb", w, onehot)
    return Histogram(hist.counts * decay + fresh, hist.log_lo, hist.log_hi)


def ingest(hist: Histogram, rows: jnp.ndarray, values: jnp.ndarray,
           valid: jnp.ndarray | None = None,
           decay: float | jnp.ndarray = 0.9) -> Histogram:
    """Scatter a flat batch of fresh observations into the decayed histogram.

    The streaming counterpart of :func:`update`: instead of re-folding a
    whole (F, W) window (O(F*W*B) via the one-hot einsum), this takes the
    S observations recorded *since the last scrape* as parallel arrays —
    ``rows[i]`` is the function-row of sample ``values[i]`` — and
    scatter-adds them, so a control tick costs O(S + F*B) regardless of
    window size.  This is what makes the 10k-function sketch control path
    (``ControlLoop(eq1="sketch")``) sub-millisecond.

    Args:
      rows: (S,) int32 destination row per sample.
      values: (S,) latency observations (seconds).
      valid: optional (S,) bool mask (padding slots False).
      decay: retention factor applied to the existing counts.
    """
    vals = jnp.asarray(values, jnp.float32)
    idx = _bucket_index(hist, vals)                      # (S,)
    w = (jnp.ones_like(vals) if valid is None
         else valid.astype(jnp.float32))
    counts = (hist.counts * decay).at[rows, idx].add(w)
    return Histogram(counts, hist.log_lo, hist.log_hi)


def quantile(hist: Histogram, q: float) -> jnp.ndarray:
    """Prometheus-style histogram_quantile: (F,) value of quantile ``q``.

    Linear interpolation inside the winning bucket, geometric bucket edges.
    Empty histograms return 0.

    Error bound (documented contract, property-tested): for observations
    inside [lo, hi], a returned quantile is off from the exact
    sorted-sample quantile by at most one geometric bucket, i.e. a
    *relative* error of ``exp((log_hi - log_lo) / B) - 1`` (~29% at the
    default 64 buckets over [1e-4, 1e3]).  Values outside [lo, hi] clamp
    into the edge buckets.  Ratios of two quantiles of the same histogram
    (Eq (1)'s p95/p50) see at most twice that relative error.
    """
    counts = hist.counts                                 # (F, B)
    B = hist.num_buckets
    total = jnp.sum(counts, axis=-1, keepdims=True)      # (F, 1)
    cum = jnp.cumsum(counts, axis=-1)                    # (F, B)
    target = q * total                                   # (F, 1)
    # First bucket where cum >= target.
    hit = cum >= jnp.maximum(target, 1e-12)
    idx = jnp.argmax(hit, axis=-1)                       # (F,)
    f = jnp.arange(counts.shape[0])
    cum_before = jnp.where(idx > 0, cum[f, jnp.maximum(idx - 1, 0)], 0.0)
    in_bucket = jnp.maximum(counts[f, idx], 1e-12)
    frac = jnp.clip((target[:, 0] - cum_before) / in_bucket, 0.0, 1.0)
    # Geometric bucket edges in log space.
    width = (hist.log_hi - hist.log_lo) / B
    log_left = hist.log_lo + idx.astype(jnp.float32) * width
    val = jnp.exp(log_left + frac * width)
    return jnp.where(total[:, 0] > 0, val, 0.0)


def quantiles(hist: Histogram, qs: Tuple[float, ...]) -> jnp.ndarray:
    """(len(qs), F) stacked quantiles."""
    return jnp.stack([quantile(hist, q) for q in qs])


def quantile_fast(hist: Histogram, qs: Tuple[float, ...]) -> jnp.ndarray:
    """(len(qs), F) stacked quantiles, tuned for the control-plane tick.

    Same bucket/interpolation rule as :func:`quantile`, but the bucket
    CDF is never fully materialized: ``jnp.cumsum`` lowers to a
    quadratic reduce-window on XLA:CPU (~1ms alone at (4096, 64), an
    order of magnitude over the whole tick budget), so this runs a
    two-level select over G=8 bucket blocks instead — block sums in one
    pass, then a scan of just the block containing each quantile.  The
    two paths differ only in float summation order (well inside the
    sketch's documented error bound); :func:`quantile` remains the
    reference implementation.
    """
    counts = hist.counts                                 # (F, B)
    F, B = counts.shape
    G = 8
    width = (hist.log_hi - hist.log_lo) / B
    if B % G == 0:
        # Two-level select: one full pass builds (F, G) block sums, the
        # target block is found with tiny (F, G) ops, then only the
        # selected B/G-wide block is gathered and scanned.  The full
        # (F, B) prefix array is never materialized — at (4096, 64)
        # that alone halves the cost vs a blocked cumsum.
        Bg = B // G
        x = counts.reshape(F, G, Bg)                     # G blocks of Bg
        blk = x.sum(-1)                                  # (F, G)
        blk_pre = blk @ jnp.triu(jnp.ones((G, G), jnp.float32), 1)
        total = blk.sum(-1, keepdims=True)               # (F, 1)
        inc = blk_pre + blk                              # inclusive prefix
        out = []
        for q in qs:
            target = jnp.maximum(q * total, 1e-12)
            # First block whose inclusive prefix reaches the target.
            b_idx = jnp.clip(jnp.sum(inc < target, -1, dtype=jnp.int32),
                             0, G - 1)                   # (F,)
            seg = jnp.take_along_axis(
                x, b_idx[:, None, None], 1)[:, 0, :]     # (F, Bg)
            seg_cum = seg @ jnp.triu(jnp.ones((Bg, Bg), jnp.float32))
            base = jnp.take_along_axis(blk_pre, b_idx[:, None], 1)
            tgt_in = target - base
            j = jnp.clip(jnp.sum(seg_cum < tgt_in, -1, dtype=jnp.int32),
                         0, Bg - 1)
            idx = b_idx * Bg + j
            cum_before = base[:, 0] + jnp.where(
                j > 0,
                jnp.take_along_axis(
                    seg_cum, jnp.maximum(j - 1, 0)[:, None], 1)[:, 0],
                0.0)
            in_bucket = jnp.maximum(
                jnp.take_along_axis(seg, j[:, None], 1)[:, 0], 1e-12)
            frac = jnp.clip((q * total[:, 0] - cum_before) / in_bucket,
                            0.0, 1.0)
            val = jnp.exp(hist.log_lo
                          + (idx.astype(jnp.float32) + frac) * width)
            out.append(jnp.where(total[:, 0] > 0, val, 0.0))
        return jnp.stack(out)
    cum = counts @ jnp.triu(jnp.ones((B, B), jnp.float32))
    total = cum[:, -1:]                                  # (F, 1)
    out = []
    for q in qs:
        target = jnp.maximum(q * total, 1e-12)
        # First bucket with cum >= target == number of buckets below it.
        idx = jnp.clip(jnp.sum(cum < target, -1, dtype=jnp.int32),
                       0, B - 1)                         # (F,)
        cum_before = jnp.where(
            idx > 0,
            jnp.take_along_axis(
                cum, jnp.maximum(idx - 1, 0)[:, None], 1)[:, 0],
            0.0)
        in_bucket = jnp.maximum(
            jnp.take_along_axis(counts, idx[:, None], 1)[:, 0], 1e-12)
        frac = jnp.clip((q * total[:, 0] - cum_before) / in_bucket,
                        0.0, 1.0)
        val = jnp.exp(hist.log_lo
                      + (idx.astype(jnp.float32) + frac) * width)
        out.append(jnp.where(total[:, 0] > 0, val, 0.0))
    return jnp.stack(out)


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Config for building per-tier histograms."""
    num_buckets: int = 64
    lo: float = 1e-4
    hi: float = 1e3
    decay: float = 0.9
