"""Streaming latency quantiles — the on-device Prometheus analogue.

The paper scrapes request latencies into Prometheus and queries p95/p50.
On a TPU there is no sidecar; instead each serving tier maintains a
*decayed log-bucketed histogram* (exactly the shape of a Prometheus
histogram with exponential buckets) as a small on-device array, updated
inside the jitted serving step. Quantiles are read with the same
interpolation rule Prometheus' ``histogram_quantile`` uses (linear within
the bucket), done in log-space because the buckets are geometric.

Everything is pure jnp: update/read are O(num_buckets) and vectorizable
over the function axis F.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Histogram:
    """Decayed log-bucket histogram, vectorized over functions.

    Attributes:
      counts: (F, B) float32 decayed bucket counts.
      log_lo: scalar — log of the smallest bucket edge.
      log_hi: scalar — log of the largest bucket edge.
    """

    def __init__(self, counts, log_lo, log_hi):
        self.counts = counts
        self.log_lo = log_lo
        self.log_hi = log_hi

    @staticmethod
    def init(num_functions: int, num_buckets: int = 64,
             lo: float = 1e-4, hi: float = 1e3) -> "Histogram":
        return Histogram(
            counts=jnp.zeros((num_functions, num_buckets), jnp.float32),
            log_lo=jnp.float32(jnp.log(lo)),
            log_hi=jnp.float32(jnp.log(hi)),
        )

    # --- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.counts, self.log_lo, self.log_hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_buckets(self) -> int:
        return self.counts.shape[-1]


def _bucket_index(hist: Histogram, x: jnp.ndarray) -> jnp.ndarray:
    """Bucket of value x (clamped into range)."""
    B = hist.num_buckets
    t = (jnp.log(jnp.maximum(x, 1e-30)) - hist.log_lo) / (hist.log_hi - hist.log_lo)
    return jnp.clip((t * B).astype(jnp.int32), 0, B - 1)


def update(hist: Histogram, latencies: jnp.ndarray,
           valid: jnp.ndarray | None = None, decay: float = 0.9) -> Histogram:
    """Fold a (F, W) window of observations into the decayed histogram.

    ``decay`` plays the role of Prometheus' retention: old observations
    fade geometrically per update call (the paper configures "short data
    liveness" for the same reason).
    """
    lat = jnp.asarray(latencies, jnp.float32)
    idx = _bucket_index(hist, lat)                      # (F, W)
    w = jnp.ones_like(lat) if valid is None else valid.astype(jnp.float32)
    onehot = jax.nn.one_hot(idx, hist.num_buckets, dtype=jnp.float32)  # (F,W,B)
    fresh = jnp.einsum("fw,fwb->fb", w, onehot)
    return Histogram(hist.counts * decay + fresh, hist.log_lo, hist.log_hi)


def quantile(hist: Histogram, q: float) -> jnp.ndarray:
    """Prometheus-style histogram_quantile: (F,) value of quantile ``q``.

    Linear interpolation inside the winning bucket, geometric bucket edges.
    Empty histograms return 0.
    """
    counts = hist.counts                                 # (F, B)
    B = hist.num_buckets
    total = jnp.sum(counts, axis=-1, keepdims=True)      # (F, 1)
    cum = jnp.cumsum(counts, axis=-1)                    # (F, B)
    target = q * total                                   # (F, 1)
    # First bucket where cum >= target.
    hit = cum >= jnp.maximum(target, 1e-12)
    idx = jnp.argmax(hit, axis=-1)                       # (F,)
    f = jnp.arange(counts.shape[0])
    cum_before = jnp.where(idx > 0, cum[f, jnp.maximum(idx - 1, 0)], 0.0)
    in_bucket = jnp.maximum(counts[f, idx], 1e-12)
    frac = jnp.clip((target[:, 0] - cum_before) / in_bucket, 0.0, 1.0)
    # Geometric bucket edges in log space.
    width = (hist.log_hi - hist.log_lo) / B
    log_left = hist.log_lo + idx.astype(jnp.float32) * width
    val = jnp.exp(log_left + frac * width)
    return jnp.where(total[:, 0] > 0, val, 0.0)


def quantiles(hist: Histogram, qs: Tuple[float, ...]) -> jnp.ndarray:
    """(len(qs), F) stacked quantiles."""
    return jnp.stack([quantile(hist, q) for q in qs])


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Config for building per-tier histograms."""
    num_buckets: int = 64
    lo: float = 1e-4
    hi: float = 1e3
    decay: float = 0.9
