"""Pallas WKV6 (RWKV "Finch") chunked scan with data-dependent decay.

The recurrence per head (state S in R^{DxD}, decay w_t in R^D per token):

    y_t = r_t . (S + u k_t v_t^T)
    S  <- diag(e^{w_t}) S + k_t v_t^T

A token-by-token loop is VPU-bound; the TPU adaptation evaluates each
chunk of C tokens in closed form with (C,D)x(D,D) and (C,C)x(C,D) MXU
matmuls (cf. models/rwkv6.wkv_chunked):

    y = (r e^{L}) S_in  +  tril_strict[(r_t k_s) e^{L_t - L_{s+1}}] v
        + diag(r_t . u k_t) v_t
    S_out = e^{L_end} S_in + (k e^{L_end - L_incl})^T v

where L is the exclusive cumulative log-decay within the chunk.

* grid = (B, H, S/C): the chunk axis is sequential ("arbitrary"); the
  (D, D) state lives in fp32 VMEM scratch across chunk steps.
* r/k/v/w tiles are (C, D) per (batch, head); D = 64 for rwkv6-7b, so a
  (64,64) state tile plus four (C,64) streams fit VMEM at C = 128-512.
* s0 is read at the first chunk; the final state is a second output
  (written at the last chunk) so serving can carry it between segments.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sfin_ref,
            s_ref, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)            # (C, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)            # log-decay <= 0
    u = u_ref[0, :]                                      # (D,)
    s = s_ref[...]                                       # (D, D)

    C = r.shape[0]
    Lincl = jnp.cumsum(w, axis=0)                        # (C, D) inclusive
    L = Lincl - w                                        # exclusive
    Lend = Lincl[-1:, :]                                 # (1, D)

    # inter-chunk: tokens see the carried state decayed by their prefix
    y_inter = jax.lax.dot_general(r * jnp.exp(L), s, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk pairwise scores with decay between s and t (s < t)
    diff = L[:, None, :] - Lincl[None, :, :]             # (t, s, D)
    A = jnp.sum(r[:, None, :] * k[None, :, :] *
                jnp.exp(jnp.minimum(diff, 0.0)), axis=-1)  # (t, s)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(si < ti, A, 0.0)
    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # bonus diagonal
    du = jnp.sum(r * u[None, :] * k, axis=-1)            # (C,)
    y = y_inter + y_intra + du[:, None] * v
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # carry the state
    kd = k * jnp.exp(jnp.minimum(Lend - Lincl, 0.0))     # (C, D)
    s_new = jnp.exp(Lend)[0, :, None] * s + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ci == nc - 1)
    def _fin():
        sfin_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, lw, u, s0, *, chunk: int = 128,
               interpret: bool = False):
    """r,k,v,lw: (B,S,H,D); u: (H,D); s0: (B,H,D,D) fp32.

    Returns (y (B,S,H,D) in r.dtype, s_final (B,H,D,D) fp32).
    """
    B, S, H, D = r.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        raise ValueError(f"seq len {S} is not divisible by chunk {chunk}")
    nc = S // chunk

    kernel = functools.partial(_kernel, nc=nc)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, D), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, sfin
