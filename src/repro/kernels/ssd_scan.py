"""Pallas selective-SSM (diagonal) chunked scan — Hymba's mamba heads.

Recurrence: h_t = a_t * h_{t-1} + b_t  (elementwise over (I, N) channels).

TPU adaptation: the channel dim I is tiled across the parallel grid (the
recurrence is independent per channel), the time axis is chunked and
iterated sequentially with the (blk_i, N) state in fp32 VMEM scratch.
Inside a chunk the recurrence is solved with an associative scan
(O(log C) VPU passes, fully VMEM-resident, stable for any decay — the
cumprod closed form underflows fp32 for strong decay).

N = ssm_state is 16 — a (blk_i, N) tile maps onto (8,128) VREGs cleanly
when blk_i is a multiple of 8 x (128/N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(a_ref, b_ref, h0_ref, hs_ref, hfin_ref, h_ref, *, nc: int,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)                    # (C, bi, N)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]                                      # (bi, N)

    # in-chunk solve: O(log C) associative-scan passes, fully VMEM-resident
    # (numerically safe for any decay, unlike the cumprod closed form)
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=0)
    hs = aa * h[None] + bb                              # (C, bi, N)
    hs_ref[0] = hs.astype(hs_ref.dtype)
    h_ref[...] = hs[-1]

    @pl.when(ci == nc - 1)
    def _fin():
        hfin_ref[0] = hs[-1]


@functools.partial(jax.jit, static_argnames=("chunk", "blk_i", "interpret"))
def ssd_scan(a, b, h0, *, chunk: int = 128, blk_i: int = 256,
             interpret: bool = False):
    """a, b: (B,S,I,N); h0: (B,I,N) fp32.

    Returns (hs (B,S,I,N) fp32, h_final (B,I,N) fp32).
    """
    B, S, I, N = a.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        raise ValueError(f"seq len {S} is not divisible by chunk {chunk}")
    blk_i = min(blk_i, I)
    pad_i = (-I) % blk_i
    if pad_i:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_i), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_i), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_i), (0, 0)))
    Ip = I + pad_i
    ni, nc = Ip // blk_i, S // chunk

    kernel = functools.partial(_kernel, nc=nc, chunk=chunk)
    hs, hfin = pl.pallas_call(
        kernel,
        grid=(B, ni, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, blk_i, N), lambda b_, ii, ci: (b_, ci, ii, 0)),
            pl.BlockSpec((1, chunk, blk_i, N), lambda b_, ii, ci: (b_, ci, ii, 0)),
            pl.BlockSpec((1, blk_i, N), lambda b_, ii, ci: (b_, ii, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, blk_i, N), lambda b_, ii, ci: (b_, ci, ii, 0)),
            pl.BlockSpec((1, blk_i, N), lambda b_, ii, ci: (b_, ii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Ip, N), jnp.float32),
            jax.ShapeDtypeStruct((B, Ip, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_i, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
    if pad_i:
        hs, hfin = hs[:, :, :I], hfin[:, :I]
    return hs, hfin
