"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel sweeps assert against
(``tests/test_kernels.py``) — deliberately naive, O(S^2) where that is the
simplest correct thing, always fp32 accumulation.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, kv_pos, window: Optional[int], causal: bool):
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = kv_pos[..., None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return ok


def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                    softcap=None):
    """O(S^2) oracle. q: (B,S,Hq,D); k/v: (B,T,Hkv,D); returns (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = _mask(q_pos, kv_pos, window, causal)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    any_ok = jnp.any(ok, axis=-1)[:, None, None, :, None]
    p = jnp.where(any_ok, p, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def decode_attention(q, k, v, q_pos, kv_pos, *, window=None, softcap=None):
    """One query token per sequence. q: (B,Hq,D); k/v: (B,T,Hkv,D)."""
    out = flash_attention(q[:, None], k, v, q_pos[:, None], kv_pos,
                          causal=True, window=window, softcap=softcap)
    return out[:, 0]


def rwkv6_scan(r, k, v, lw, u, s0):
    """Literal WKV6 recurrence. r,k,v,lw: (B,S,H,D) fp32; u: (H,D);
    s0: (B,H,D,D). Returns (y (B,S,H,D), s_final)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                                 # (B,H,D)
        kv = kt[..., :, None] * vt[..., None, :]             # (B,H,D,D)
        y = jnp.einsum("bhd,bhde->bhe", rt, s + u[..., :, None] * kv)
        s = jnp.exp(wt)[..., :, None] * s + kv
        return s, y
    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
               for a in (r, k, v, lw))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), s_fin


def ssd_scan(a, b, h0):
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + b_t (selective SSM).

    a, b: (B,S,I,N) fp32; h0: (B,I,N). Returns (hs (B,S,I,N), h_final)."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h
    xs = (a.transpose(1, 0, 2, 3).astype(jnp.float32),
          b.transpose(1, 0, 2, 3).astype(jnp.float32))
    h_fin, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return hs.transpose(1, 0, 2, 3), h_fin
