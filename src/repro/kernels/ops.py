"""Jit'd public wrappers over the Pallas kernels.

Call sites go through these (``cfg.use_pallas=True`` flips the model code
here); each op:

* pads/validates shapes, picks TPU-aligned block sizes;
* runs ``interpret=True`` automatically on CPU (the container target) and
  compiled Mosaic on TPU;
* carries a ``custom_vjp`` whose backward recomputes through the pure-jnp
  oracle (``ref.py``) — numerically identical to differentiating the
  oracle, so training through kernels needs no hand-written backward
  kernels while inference gets the fused forward.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ref
from repro.kernels import rwkv6_scan as _rwkv
from repro.kernels import ssd_scan as _ssd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_pos, kv_pos, causal=True, window=None,
                    softcap=None):
    return _fa.flash_attention(q, k, v, q_pos, kv_pos, causal=causal,
                               window=window, softcap=softcap,
                               interpret=_on_cpu())


def _fa_fwd(q, k, v, q_pos, kv_pos, causal, window, softcap):
    out = flash_attention(q, k, v, q_pos, kv_pos, causal, window, softcap)
    return out, (q, k, v, q_pos, kv_pos)


def _fa_bwd(causal, window, softcap, res, g):
    q, k, v, q_pos, kv_pos = res
    def f(q, k, v):
        return ref.flash_attention(q, k, v, q_pos, kv_pos, causal=causal,
                                   window=window, softcap=softcap)
    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# --------------------------------------------------------------------------
# decode attention (inference only — no vjp needed, but harmless)
# --------------------------------------------------------------------------


def decode_attention(q, k, v, q_pos, kv_pos, window=None, softcap=None):
    return _dec.decode_attention(q, k, v, q_pos, kv_pos, window=window,
                                 softcap=softcap, interpret=_on_cpu())


def paged_decode_attention(q, k_pages, v_pages, page_tables, q_pos,
                           kv_pos_pages, window=None, softcap=None):
    """Flash-decode straight off a paged KV pool (no gather roundtrip):
    K/V tiles stream through the request's page table via scalar
    prefetch.  Bit-identical to ``decode_attention`` with
    ``blk_k=page_size`` on the gathered view."""
    return _dec.paged_decode_attention(q, k_pages, v_pages, page_tables,
                                       q_pos, kv_pos_pages, window=window,
                                       softcap=softcap, interpret=_on_cpu())


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------


@jax.custom_vjp
def rwkv6_scan(r, k, v, lw, u, s0):
    return _rwkv.rwkv6_scan(r, k, v, lw, u, s0, interpret=_on_cpu())


def _rwkv_fwd(r, k, v, lw, u, s0):
    return rwkv6_scan(r, k, v, lw, u, s0), (r, k, v, lw, u, s0)


def _rwkv_bwd(res, g):
    r, k, v, lw, u, s0 = res
    _, vjp = jax.vjp(lambda *a: ref.rwkv6_scan(*a), r, k, v, lw, u, s0)
    return vjp(g)


rwkv6_scan.defvjp(_rwkv_fwd, _rwkv_bwd)


# --------------------------------------------------------------------------
# selective-SSM scan
# --------------------------------------------------------------------------


@jax.custom_vjp
def ssd_scan(a, b, h0):
    return _ssd.ssd_scan(a, b, h0, interpret=_on_cpu())


def _ssd_fwd(a, b, h0):
    return ssd_scan(a, b, h0), (a, b, h0)


def _ssd_bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(lambda *x: ref.ssd_scan(*x), a, b, h0)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)
