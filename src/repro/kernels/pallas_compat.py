"""Version compatibility for the Pallas TPU surface.

The kernels in this package target the current Pallas API, where the
Mosaic compiler-parameter dataclass is ``pltpu.CompilerParams``.  Older
jax releases (< 0.5, including the one baked into this image) expose the
same dataclass as ``pltpu.TPUCompilerParams``.  Resolve the name once
here so every kernel module works (and its CPU ``interpret=True`` tests
run) on either release.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
