"""Pallas TPU flash attention (GQA, causal, sliding-window, softcap).

Blocked online-softmax, the TPU-native adaptation of FlashAttention:

* grid = (batch, q_heads, S/blk_q, T/blk_k); the kv axis is the innermost,
  sequentially-iterated dimension ("arbitrary" semantics) so the running
  max / denominator / accumulator live in VMEM scratch across kv steps.
* BlockSpecs tile q and out to (blk_q, head_dim) and k/v to
  (blk_k, head_dim) per (batch, head) — MXU-aligned when blk_* are
  multiples of 128 and head_dim is 64/128.
* Masking is positional (absolute positions for q and kv): causality,
  sliding windows and empty cache slots (pos < 0) are one predicate, so
  the same kernel serves training, prefill and rolling-buffer caches.
* GQA: query head h reads kv head h // (Hq // Hkv) — no head replication
  in HBM.

Validated against ``ref.flash_attention`` in interpret mode on CPU
(tests/test_kernels.py sweeps shapes/dtypes/windows).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
            window: Optional[int], softcap: Optional[float], nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale        # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (bk, D)
    qp = qp_ref[0, :]                                        # (bq,)
    kp = kp_ref[0, :]                                        # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    d = qp[:, None] - kp[None, :]
    ok = kp[None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alive = m_new > NEG_INF / 2
    p = jnp.where(alive[:, None], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _out():
        den = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / den).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False):
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D); q_pos: (B,S); kv_pos: (B,T).

    Returns (B,S,Hq,D) in q.dtype.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    blk_q = min(blk_q, S)
    blk_k = min(blk_k, T)
    pad_s = (-S) % blk_q
    pad_t = (-T) % blk_k
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_t)), constant_values=-1)
    Sp, Tp = S + pad_s, T + pad_t
    nq, nk = Sp // blk_q, Tp // blk_k

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, blk_q), lambda b, h, qi, ki: (b, qi)),
            pl.BlockSpec((1, blk_k), lambda b, h, qi, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
    return out[:, :S]
