"""Pallas flash-decode: one query token vs a large KV cache.

Decode is memory-bound (read T x Hkv x D cache bytes per generated token),
so the kernel's job is to stream the cache through VMEM exactly once, in
bf16, with fp32 accumulators in scratch:

* grid = (batch, kv_heads, T/blk_k); the kv axis iterates sequentially and
  carries (acc, m, l) for all G = Hq/Hkv query heads of this kv head.
* q is tiled (G, D) per (batch, kv head); k/v stream (blk_k, D) tiles.
* The cache may be a rolling buffer: slot validity and causality are
  positional predicates on kv_pos (pos < 0 = empty slot), identical to
  the prefill kernel's rule.

This is the kernel the paper-representative decode cells hillclimb onto:
it removes the fp32 cache materialization the XLA baseline exhibits (see
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float,
            window: Optional[int], softcap: Optional[float], nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qg = q_ref[0, 0, :, :].astype(jnp.float32) * scale       # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (bk, D)
    qp = qp_ref[0]                                           # ()
    kp = kp_ref[0, :]                                        # (bk,)

    s = jax.lax.dot_general(qg, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    d = qp - kp
    ok = (kp >= 0) & (d >= 0)
    if window is not None:
        ok &= d < window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alive = m_new > NEG_INF / 2
    p = jnp.where(alive[:, None], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _out():
        den = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / den).astype(o_ref.dtype)


def _paged_kernel(pt_ref, q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float,
                  window: Optional[int], softcap: Optional[float], nk: int):
    """Same online-softmax body as :func:`_kernel`, but each kv step's
    K/V tile is fetched *through the page table*: the BlockSpec index map
    reads ``pt_ref`` (scalar-prefetched, so the DMA address is known
    before the step runs) and pulls page ``pt[b, ki]`` of the pool
    instead of the ki-th contiguous tile of a dense row.  Pages holding
    no valid positions (the null page a short row's table is padded
    with) contribute nothing: their ``kv_pos`` entries are -1, the same
    predicate that masks empty slots of a dense rolling cache.  With
    ``blk_k == page_size`` the reduction order over positions is
    identical to the dense kernel's, so outputs match bit-for-bit."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qg = q_ref[0, 0, :, :].astype(jnp.float32) * scale       # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (page, D)
    qp = qp_ref[0]                                           # ()
    kp = kp_ref[0, :]                                        # (page,)

    s = jax.lax.dot_general(qg, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    d = qp - kp
    ok = (kp >= 0) & (d >= 0)
    if window is not None:
        ok &= d < window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alive = m_new > NEG_INF / 2
    p = jnp.where(alive[:, None], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _out():
        den = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / den).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_tables, q_pos,
                           kv_pos_pages, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = False):
    """Flash-decode over a paged KV pool.

    q: (B,Hq,D); k_pages/v_pages: (P, page, Hkv, D) — the page pool;
    page_tables: (B, pages_per_row) int32, short rows padded with the id
    of a scrubbed null page (kv_pos == -1 everywhere); q_pos: (B,);
    kv_pos_pages: (P, page).

    Returns (B,Hq,D) in q.dtype — bit-identical to ``decode_attention``
    with ``blk_k=page`` on the gathered contiguous view.
    """
    B, Hq, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    ppr = page_tables.shape[1]
    G = Hq // Hkv
    scale = D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               softcap=softcap, nk=ppr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, ppr),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ki, pt: (pt[b, ki], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ki, pt: (pt[b, ki], 0, h, 0)),
            pl.BlockSpec((1,), lambda b, h, ki, pt: (b,)),
            pl.BlockSpec((1, page), lambda b, h, ki, pt: (pt[b, ki], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_tables, qg, k_pages, v_pages, q_pos, kv_pos_pages)
    return out.reshape(B, Hq, D)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "blk_k",
                                             "interpret"))
def decode_attention(q, k, v, q_pos, kv_pos, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     blk_k: int = 512, interpret: bool = False):
    """q: (B,Hq,D); k/v: (B,T,Hkv,D); q_pos: (B,); kv_pos: (B,T).

    Returns (B,Hq,D) in q.dtype.
    """
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    blk_k = min(blk_k, T)
    pad_t = (-T) % blk_k
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_t)), constant_values=-1)
    Tp = T + pad_t
    nk = Tp // blk_k

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((1, blk_k), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, q_pos, kv_pos)
    return out.reshape(B, Hq, D)
