"""Logical-axis sharding: one vocabulary, three interpreters.

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "ffn", "vocab", "batch", "cache_seq", ...). A
:class:`AxisRules` object — built by the launcher for a concrete mesh and
run mode — maps logical names to mesh axes. Three consumers:

* ``shd(x, *axes)``      — in-graph ``with_sharding_constraint`` on
  activations (no-op when no rules are installed, so unit tests and the
  single-device smoke path run unchanged);
* ``param_partition_spec(spec, rules)`` — PartitionSpec for a ParamSpec;
* the launcher builds ``in_shardings``/``out_shardings`` for ``jax.jit``
  from whole param/cache tables.

Modes differ only in the mapping (see ``launch/sharding.py`` for the
tables): training adds FSDP ("embed" -> "data"), serving keeps weights
replicated across "data" and shards the KV cache sequence over "model"
(flash-decode style), etc.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Logical-axis -> mesh-axis mapping bound to a mesh."""

    mesh: Mesh
    map: Dict[str, MeshAxes]

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tuple of logical axis names.

        When ``shape`` is given, mesh axes that do not evenly divide the
        dimension are dropped (trailing-first), so e.g. 8 KV heads on a
        16-way "model" axis silently fall back to replication instead of
        producing an invalid sharding. This makes one rule table valid
        across all ten architectures.
        """
        entries = []
        used: set = set()
        for i, ax in enumerate(axes):
            m = self.map.get(ax) if ax is not None else None
            if m is None:
                entries.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            # A mesh axis may appear only once per spec; later dims lose.
            ms = tuple(a for a in ms if a not in used and a in self.mesh.axis_names)
            if shape is not None:
                # Drop trailing mesh axes until the shard count divides.
                def size(t):
                    n = 1
                    for a in t:
                        n *= self.mesh.shape[a]
                    return n
                while ms and shape[i] % size(ms) != 0:
                    ms = ms[:-1]
            used.update(ms)
            if not ms:
                entries.append(None)
            elif len(ms) == 1:
                entries.append(ms[0])
            else:
                entries.append(ms)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))


_local = threading.local()


def set_rules(rules: Optional[AxisRules]) -> None:
    _local.rules = rules


def get_rules() -> Optional[AxisRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def set_param_rules(rules: Optional[AxisRules]) -> None:
    _local.param_rules = rules


def get_param_rules() -> Optional[AxisRules]:
    return getattr(_local, "param_rules", None)


@contextlib.contextmanager
def use_param_rules(rules: Optional[AxisRules]):
    """Install the *parameter* rule table (used by in-layer weight
    constraints: pinning a weight's sharding at its use site also pins the
    cotangent — the lever that turns per-layer grad all-reduces into
    reduce-scatters under FSDP; see EXPERIMENTS.md §Perf cell B)."""
    prev = get_param_rules()
    set_param_rules(rules)
    try:
        yield
    finally:
        set_param_rules(prev)


def shd(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op
    outside an installed AxisRules context)."""
    rules = get_rules()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} do not match array rank "
                         f"{x.ndim} (shape {x.shape})")
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes, x.shape))
