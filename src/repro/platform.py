"""``repro.platform`` — the one front door to the continuum.

Every deployment of the paper's platform — the discrete-event simulator
(§4, Table 2 / Figure 2) and the live two-tier serving runtime — is
driven by the same :class:`repro.core.policy.Policy` objects through the
same :class:`repro.core.policy.ControlLoop`.  This facade is the single
entry point the launchers, examples and benchmarks use:

    from repro.platform import Continuum, TierConfig

    # live: deploy models, submit requests, tick the batched scheduler
    cc = Continuum(edge=TierConfig(slots=2), cloud=TierConfig(slots=16),
                   policy="auto")
    cc.deploy(spec, model_cfg, params)
    cc.submit("fn", request)
    cc.tick()

    # simulated: the paper's testbed, same policy objects
    res = Continuum.simulate("matmult", policy="auto+net")
    table = Continuum.sweep("matmult", policies=(0.0, 50.0, "auto"))

Policy shorthands accepted everywhere: a number in [0, 100] (static
split), ``"auto"`` (paper Eqs (1)-(4)), ``"auto+net"`` (link-capacity
cap), ``"auto+hedge"`` (p99 straggler hedging), or any
:class:`~repro.core.policy.Policy` instance.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core import offload
from repro.core.policy import (AutoOffload, ControlLoop, HedgedOffload,
                               NetAwareOffload, Policy, PolicySpec,
                               StaticSplit)
from repro.core.simulator import ContinuumSimulator, SimConfig, SimResult
from repro.serving.engine import Request
from repro.serving.tiers import EdgeCloudContinuum, TierConfig

__all__ = [
    "Continuum", "TierConfig", "SimConfig", "SimResult", "Request",
    "Policy", "StaticSplit", "AutoOffload", "NetAwareOffload",
    "HedgedOffload", "ControlLoop",
]


class Continuum(EdgeCloudContinuum):
    """Unified control plane over both deployments.

    Instances are the live batched runtime (see
    :class:`~repro.serving.tiers.EdgeCloudContinuum`); the classmethods run
    the same policies through the calibrated simulator.
    """

    @classmethod
    def simulate(cls, workload: str, policy: PolicySpec,
                 cfg: Optional[SimConfig] = None,
                 offload_cfg: Optional[offload.OffloadConfig] = None
                 ) -> SimResult:
        """One simulator run of ``workload`` under ``policy``."""
        return ContinuumSimulator(workload, policy, cfg or SimConfig(),
                                  offload_cfg=offload_cfg).run()

    @classmethod
    def sweep(cls, workload: str,
              policies: Sequence[PolicySpec] = (0.0, 25.0, 50.0, 75.0,
                                                100.0, "auto"),
              cfg: Optional[SimConfig] = None) -> Dict[str, SimResult]:
        """The paper's Table 2 row for one workload."""
        cfg = cfg or SimConfig()
        return {str(p): cls.simulate(workload, p, cfg) for p in policies}
