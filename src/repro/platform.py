"""``repro.platform`` — the one front door to the continuum.

Every deployment of the paper's platform — the discrete-event simulator
(§4, Table 2 / Figure 2) and the live N-tier serving runtime — is driven
by the same :class:`repro.core.policy.Policy` objects through the same
:class:`repro.core.policy.ControlLoop`, over the same declarative
:class:`repro.core.topology.Topology`.  This facade is the single entry
point the launchers, examples and benchmarks use:

    from repro.platform import Continuum, TierConfig, Topology, TierSpec

    # live, two-tier sugar: deploy models, submit requests, tick
    cc = Continuum(edge=TierConfig(slots=2), cloud=TierConfig(slots=16),
                   policy="auto")
    cc.deploy(spec, model_cfg, params)
    cc.submit("fn", request)       # ingress Gateway (bounded backlog)
    cc.tick()                      # scrape -> route -> continuous batching
                                   # (admit -> decode step -> retire/cancel;
                                   #  scheduler="wave" keeps the legacy drain)

    # live, N-tier: declare the chain explicitly
    topo = Topology(tiers=(TierSpec("device", slots=1),
                           TierSpec("edge", slots=4),
                           TierSpec("cloud", slots=16)),
                    links=(LinkSpec(rtt_s=0.005), LinkSpec(rtt_s=0.04)))
    cc = Continuum.from_topology(topo, policy="auto")

    # simulated: the paper's testbed, same policy objects, any topology
    res = Continuum.simulate("matmult", policy="auto+net")
    res3 = Continuum.simulate("matmult", "auto",
                              topology=Topology.device_edge_cloud())
    table = Continuum.sweep("matmult", policies=(0.0, 50.0, "auto"))

    # cost-modeled tiers: name a zoo model (and a mesh for sharded
    # multi-device tiers) and slots/decode_step_ms/service_rate_mult are
    # derived from hlo_cost rooflines — one cost model for sim AND live
    topo = Topology.device_edge_cloud(cost_model=True)   # 1.6B/14B/405B
    topo = Topology.costed((TierSpec("edge", slots=4,
                                     model="qwen2.5-14b",
                                     mesh_shape=(1, 2)),
                            TierSpec("cloud", slots=64,
                                     model="llama3-405b",
                                     mesh_shape=(16, 16))))
    cost = tier_cost("llama3-405b", mesh_shape=(16, 16))  # the numbers

    # traces & chaos (repro.workloads): both deployments accept the same
    # workload trace and timed fault schedule
    tr = Trace.bursty(base_rps=2.0, burst_rps=24.0, duration_s=120.0)
    res = Continuum.simulate("io", "auto+migrate", trace=tr,
                             faults=edge_brownout(30.0, 60.0))
    cc = Continuum.from_topology(topo, policy="auto+migrate", trace=tr,
                                 faults=edge_brownout(30.0, 60.0))

Policy shorthands accepted everywhere: a number in [0, 100] (static
split), ``"auto"`` (paper Eqs (1)-(4)), ``"auto+net"`` (link-capacity
cap), ``"auto+hedge"`` (p99 straggler hedging), or any
:class:`~repro.core.policy.Policy` instance.  Over N tiers, each boundary
runs the same controller and the per-boundary R_t compose into a routing
distribution (waterfall offloading); two tiers reduce to the paper's
single scalar R_t exactly.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core import offload
from repro.core.policy import (AutoOffload, ControlLoop, HedgedOffload,
                               MigratingOffload, NetAwareOffload, Policy,
                               PolicySpec, StaticSplit)
from repro.core.simulator import ContinuumSimulator, SimConfig, SimResult
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.serving.engine import Request
from repro.serving.tiers import EdgeCloudContinuum, Gateway, TierConfig
from repro.workloads.faults import (FaultEvent, FaultSchedule,
                                    cloud_partition, edge_brownout,
                                    merge_schedules, tier_outage)
from repro.workloads.trace import Trace

__all__ = [
    "Continuum", "TierConfig", "TierSpec", "LinkSpec", "Topology",
    "Gateway", "SimConfig", "SimResult", "Request",
    "Policy", "StaticSplit", "AutoOffload", "NetAwareOffload",
    "HedgedOffload", "MigratingOffload", "ControlLoop",
    "Trace", "FaultEvent", "FaultSchedule",
    "edge_brownout", "cloud_partition", "tier_outage", "merge_schedules",
    "tier_cost", "TierCost",
]


def tier_cost(arch: str, **kwargs):
    """Price one cost-modeled tier (see
    :func:`repro.launch.tier_cost.tier_cost`).  Deferred import: the
    pricing pulls in the jax-heavy launch stack only when asked."""
    from repro.launch import tier_cost as _tc
    return _tc.tier_cost(arch, **kwargs)


def __getattr__(name: str):
    if name == "TierCost":
        from repro.launch.tier_cost import TierCost
        return TierCost
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class Continuum(EdgeCloudContinuum):
    """Unified control plane over both deployments.

    Instances are the live batched runtime (see
    :class:`~repro.serving.tiers.EdgeCloudContinuum`); the classmethods run
    the same policies through the calibrated simulator.
    """

    @classmethod
    def from_topology(cls, topology: Topology, policy: PolicySpec = "auto",
                      **kwargs) -> "Continuum":
        """The live runtime over an explicit N-tier chain."""
        return cls(policy=policy, topology=topology, **kwargs)

    def drain(self, max_ticks: int = 1000) -> int:
        """Tick until every gateway backlog and in-flight slot is empty
        (useful after a ``max_steps_per_tick``-paced run, where long
        requests stay slot-resident across ticks).  Returns the number of
        ticks it took; raises if ``max_ticks`` is not enough."""
        for n in range(max_ticks):
            if self.queued == 0 and self.in_flight == 0:
                return n
            self.tick()
        if self.queued or self.in_flight:
            raise RuntimeError(
                f"drain: {self.queued} queued / {self.in_flight} in flight "
                f"after {max_ticks} ticks")
        return max_ticks

    @classmethod
    def simulate(cls, workload: str, policy: PolicySpec,
                 cfg: Optional[SimConfig] = None,
                 offload_cfg: Optional[offload.OffloadConfig] = None,
                 topology: Optional[Topology] = None,
                 trace=None, faults: Optional[FaultSchedule] = None,
                 eq1: str = "window", sketch=None) -> SimResult:
        """One simulator run of ``workload`` under ``policy`` (over the
        paper's 2-tier apparatus, or any explicit ``topology``); an
        optional :class:`~repro.workloads.trace.Trace` replaces the
        built-in ramped-Poisson arrivals and an optional
        :class:`~repro.workloads.faults.FaultSchedule` injects link/tier
        faults mid-run.  ``eq1="sketch"`` switches the control loop to
        the streaming-sketch Eq-(1) front end (see docs/architecture.md),
        with an optional :class:`~repro.core.quantile.SketchSpec`."""
        return ContinuumSimulator(workload, policy, cfg or SimConfig(),
                                  offload_cfg=offload_cfg,
                                  topology=topology,
                                  trace=trace, faults=faults,
                                  eq1=eq1, sketch=sketch).run()

    @classmethod
    def sweep(cls, workload: str,
              policies: Sequence[PolicySpec] = (0.0, 25.0, 50.0, 75.0,
                                                100.0, "auto"),
              cfg: Optional[SimConfig] = None,
              topology: Optional[Topology] = None,
              trace=None, faults: Optional[FaultSchedule] = None
              ) -> Dict[str, SimResult]:
        """The paper's Table 2 row for one workload."""
        cfg = cfg or SimConfig()
        return {str(p): cls.simulate(workload, p, cfg, topology=topology,
                                     trace=trace, faults=faults)
                for p in policies}
